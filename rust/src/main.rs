//! gunrock CLI — the L3 coordinator entry point.
//!
//! Subcommands:
//!   run <primitive>    run a primitive on a dataset analog or graph file
//!   serve              concurrent query service (stdin protocol or --demo)
//!   generate           emit a synthetic dataset to an edge-list file
//!   convert            compress a graph into the .gsr container
//!   stats              report bits/edge for every codec on a graph
//!   info               print dataset topology properties (Table 4 columns)
//!   datasets           list registered paper-dataset analogs
//!
//! Examples:
//!   gunrock run bfs --dataset soc-orkut --direction-optimized
//!   gunrock run sssp --dataset roadnet_USA --strategy twc
//!   gunrock convert --dataset rmat_s22_e64 --codec zeta2 --out /tmp/rmat.gsr
//!   gunrock run bfs --graph /tmp/rmat.gsr          # decode-on-advance
//!   gunrock stats --dataset soc-orkut
//!   gunrock serve --dataset soc-livejournal1 --demo 1000
//!   gunrock generate --dataset rmat_s22_e64 --out /tmp/rmat.txt
//!
//! Every primitive invocation — `run`, `serve`, and programmatic callers —
//! dispatches through `primitives::api`, the one entry surface.

use anyhow::{bail, Context, Result};

use gunrock::config::{cli, Config};
use gunrock::graph::compressed::{raw_csr_bytes, Codec, CompressedCsr};
use gunrock::graph::{datasets, io, properties, GraphRep};
use gunrock::harness;
use gunrock::primitives::api::{self, Output, PrimitiveKind, QueryError, Request};
use gunrock::primitives::{bfs, sssp};
use gunrock::service::{protocol, Answer, Query, QueryService};

const BOOL_FLAGS: &[&str] = &[
    "direction-optimized",
    "idempotence",
    "weighted",
    "undirected",
    "pull",
    "no-in-edges",
    "obs",
    "mmap",
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "gunrock — Gunrock: GPU Graph Analytics (TOPC 2017), CPU-simulated reproduction\n\
         \n\
         USAGE: gunrock <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS\n\
           run <bfs|sssp|bc|pagerank|cc|tc|wtf|ppr|mst|color|mis|lp|radii>\n\
                                                  run a primitive (every primitive\n\
                                                  traverses .gsr compressed-natively)\n\
           serve                                  concurrent query service: point\n\
                                                  queries batched 64 sources wide\n\
                                                  (stdin protocol, or --demo <n>)\n\
           convert                                compress to .gsr (--out, --codec;\n\
                                                  in-edge view by default)\n\
           stats                                  bits/edge per codec for a graph\n\
           info                                   dataset topology properties\n\
           generate                               write a dataset analog to a file\n\
           datasets                               list paper-dataset analogs\n\
         \n\
         COMMON FLAGS\n\
           --dataset <name>      paper dataset analog (see `gunrock datasets`)\n\
           --graph <path>        load .mtx, .gsr, or edge-list file instead\n\
           --codec <c>           .gsr gap codec: varint (default) | zeta1..zeta8\n\
           --no-in-edges          convert: skip the .gsr v2 in-edge section\n\
           --out <path>          output path (convert, generate)\n\
           --mmap                 map .gsr files zero-copy (page-cache windows)\n\
                                  instead of reading them into owned buffers\n\
           --mmap-validate <v>   mapped-load checks: bounds | checksums\n\
                                  (default) | full\n\
           --spill-dir <dir>     convert: build out-of-core, spilling sorted\n\
                                  edge runs to this directory\n\
           --batch-edges <n>     convert: spill batch budget in edge records\n\
                                  (default 4194304)\n\
           --config <path>       TOML config file\n\
           --threads <n>         worker threads (default: all cores)\n\
           --pool-threads <n>    persistent pool width (default: --threads)\n\
           --strategy <s>        ThreadExpand|TWC|LB|LB_LIGHT|LB_CULL (default auto)\n\
           --src <v>             source vertex (default: max-degree vertex)\n\
           --direction-optimized  enable push/pull switching (BFS)\n\
           --idempotence          enable idempotent advance (BFS)\n\
           --pull                 pagerank: pull-mode gather (needs in-edge view)\n\
           --do-a <f> --do-b <f>  direction heuristic parameters\n\
           --delta <n>            SSSP near/far delta (0 = Bellman-Ford)\n\
           --frontier-switch <f>  hybrid frontier densify threshold as a\n\
                                  fraction of m (default 0.05)\n\
           --frontier-mode <m>    frontier representation: auto (default)\n\
                                  | sparse | dense\n\
           --trace <path>        write a Chrome trace_event JSON of the run\n\
                                  (chrome://tracing, Perfetto); implies --obs\n\
           --obs                  arm observability (event rings + metrics\n\
                                  registry + flight recorder) without a trace\n\
           --obs-ring <n>        per-thread event-ring capacity (default 4096)\n\
         \n\
         SERVE FLAGS\n\
           --demo <n>            answer n synthetic mixed queries, print stats\n\
           --max-queue <n>       admission-control queue limit (default 4096)\n\
           --lanes <n>           batch width, 1..=64 (default 64)\n\
           --cache <n>           landmark-cache capacity (default 1024)\n\
           --deadline-ms <n>     per-query deadline; an expired query answers\n\
                                  'error: deadline exceeded' (0 = unlimited)\n\
           --max-retries <n>     batch retries after a caught engine panic\n\
                                  before per-source isolation (default 2)\n\
           --shed-after-ms <n>   shed queries older than this at drain time\n\
                                  (0 = never shed)\n\
           --mem-budget <mb>     memory budget for the resource governor;\n\
                                  over-budget queries are rejected and the\n\
                                  degradation ladder arms (0 = unlimited)\n\
         \n\
         SERVE PROTOCOL (stdin, one query per line)\n\
           bfs <src> <dst>       hop count src -> dst (or 'unreachable')\n\
           sssp <src> <dst>      shortest-path distance src -> dst\n\
           ppr <user>            top-k personalized-PageRank recommendations\n\
           stats                 service counters (served, batches, cache hits)\n\
           metrics               JSON metrics snapshot (queue depth, per-kind\n\
                                  pending, counters) + Prometheus-style text\n\
           health                governor health JSON: ladder level, memory\n\
                                  pressure, per-class usage, denials\n\
           quit                  shut down\n"
    );
}

fn build_config(p: &cli::ParsedArgs) -> Result<Config> {
    let mut cfg = match p.get("config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    if let Some(t) = p.get_parse::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(t) = p.get_parse::<usize>("pool-threads")? {
        cfg.pool_threads = t;
    }
    if let Some(s) = p.get("strategy") {
        cfg.strategy = Some(s.parse().map_err(anyhow::Error::msg)?);
    }
    if p.get_bool("direction-optimized") {
        cfg.direction_optimized = true;
    }
    if p.get_bool("idempotence") {
        cfg.idempotence = true;
    }
    if let Some(v) = p.get_parse::<f64>("do-a")? {
        cfg.do_a = v;
    }
    if let Some(v) = p.get_parse::<f64>("do-b")? {
        cfg.do_b = v;
    }
    if let Some(v) = p.get_parse::<u64>("delta")? {
        cfg.sssp_delta = v;
    }
    if let Some(v) = p.get_parse::<f64>("frontier-switch")? {
        cfg.frontier_switch = v;
    }
    if let Some(s) = p.get("frontier-mode") {
        cfg.frontier_mode = s.parse().map_err(anyhow::Error::msg)?;
    }
    if let Some(v) = p.get_parse::<usize>("max-queue")? {
        cfg.service_max_queue = v;
    }
    if let Some(v) = p.get_parse::<usize>("lanes")? {
        cfg.service_lanes = v;
    }
    if let Some(v) = p.get_parse::<usize>("cache")? {
        cfg.service_cache = v;
    }
    if let Some(v) = p.get_parse::<u64>("deadline-ms")? {
        cfg.service_deadline_ms = v;
    }
    if let Some(v) = p.get_parse::<u32>("max-retries")? {
        cfg.service_max_retries = v;
    }
    if let Some(v) = p.get_parse::<u64>("shed-after-ms")? {
        cfg.service_shed_after_ms = v;
    }
    if let Some(v) = p.get_parse::<u64>("mem-budget")? {
        cfg.resources_mem_budget_mb = v;
    }
    if p.get_bool("obs") {
        cfg.obs_enable = true;
    }
    if let Some(v) = p.get_parse::<usize>("obs-ring")? {
        cfg.obs_ring = v;
    }
    if let Some(path) = p.get("trace") {
        cfg.obs_trace = path.to_string();
    }
    if p.get_bool("mmap") {
        cfg.storage_mmap = true;
    }
    if let Some(s) = p.get("mmap-validate") {
        cfg.storage_mmap_validate = s.parse()?;
    }
    if let Some(d) = p.get("spill-dir") {
        cfg.storage_spill_dir = d.to_string();
    }
    if let Some(v) = p.get_parse::<usize>("batch-edges")? {
        cfg.storage_batch_edges = v;
    }
    // --trace implies arming: a trace of a disabled subsystem is empty.
    if !cfg.obs_trace.is_empty() {
        cfg.obs_enable = true;
    }
    gunrock::obs::configure(cfg.obs_enable, cfg.obs_ring);
    if cfg.resources_mem_budget_mb > 0 {
        gunrock::util::resources::governor().set_budget_mb(cfg.resources_mem_budget_mb);
    }
    Ok(cfg)
}

/// Flush the Chrome trace at CLI exit when `--trace <path>` asked for one.
fn finish_trace(cfg: &Config) -> Result<()> {
    if !cfg.obs_trace.is_empty() {
        gunrock::obs::export::write_chrome_trace(&cfg.obs_trace)
            .with_context(|| format!("write trace {}", cfg.obs_trace))?;
        println!(
            "wrote Chrome trace ({} events recorded) to {}",
            gunrock::obs::total_events_written(),
            cfg.obs_trace
        );
    }
    Ok(())
}

/// SSSP/MST need weights. When the source (file, dataset analog — some,
/// like the WTF follow graphs, ignore the `weighted` request — or `.gsr`
/// container) provides none, attach the deterministic positional array:
/// one seed, one code path, so every representation of the same graph
/// gets the identical weights and runs stay bit-comparable across them.
fn ensure_uniform_weights(
    weights: &mut Vec<gunrock::graph::Weight>,
    num_edges: usize,
    weighted: bool,
) {
    if weighted && weights.is_empty() {
        *weights = datasets::uniform_weights(num_edges, 42);
    }
}

/// Load a `.gsr` container honoring the storage config: `--mmap` maps it
/// zero-copy (payload windows into the page cache, validated to
/// `--mmap-validate` depth), otherwise the owned loader reads and fully
/// verifies the file.
fn load_gsr_cfg(path: &std::path::Path, cfg: &Config) -> Result<CompressedCsr> {
    if cfg.storage_mmap {
        io::load_gsr_mmap(path, cfg.storage_mmap_validate)
    } else {
        io::load_gsr(path)
    }
}

fn load_graph(p: &cli::ParsedArgs, weighted: bool) -> Result<(String, gunrock::graph::Csr)> {
    let (name, mut g) = if let Some(path) = p.get("graph") {
        let g = io::load_graph(std::path::Path::new(path), p.get_bool("undirected"))?;
        (path.to_string(), g)
    } else {
        let name = p.get_or("dataset", "rmat_s22_e64").to_string();
        let g = datasets::try_load(&name, weighted)
            .ok_or(QueryError::UnknownDataset(name.clone()))?;
        (name, g)
    };
    let m = g.num_edges();
    ensure_uniform_weights(&mut g.edge_weights, m, weighted);
    Ok((name, g))
}

fn run(args: &[String]) -> Result<()> {
    let p = cli::parse(args, BOOL_FLAGS)?;
    match p.subcommand.as_deref() {
        None | Some("help") | Some("--help") => {
            usage();
            Ok(())
        }
        Some("datasets") => {
            println!("paper dataset -> analog (see graph::datasets)");
            for name in datasets::TABLE4 {
                let spec = datasets::spec(name);
                println!("  {:18} {:?}: {}", name, spec.class, spec.description);
            }
            for name in datasets::WTF_DATASETS {
                let spec = datasets::spec(name);
                println!("  {:18} {:?}: {}", name, spec.class, spec.description);
            }
            Ok(())
        }
        Some("info") => {
            let (name, g) = load_graph(&p, false)?;
            let props = properties::analyze(&g);
            println!("dataset: {name}");
            println!("  vertices:        {}", props.vertices);
            println!("  edges:           {}", props.edges);
            println!("  max degree:      {}", props.max_degree);
            println!("  avg degree:      {:.2}", props.avg_degree);
            println!("  degree stddev:   {:.2}", props.degree_stddev);
            println!("  pseudo-diameter: {}", props.pseudo_diameter);
            println!("  deg<64 fraction: {:.2}", props.frac_low_degree);
            println!("  class:           {}", if props.is_scale_free() { "scale-free" } else { "mesh-like" });
            Ok(())
        }
        Some("generate") => {
            let (name, g) = load_graph(&p, p.get_bool("weighted"))?;
            let out = p.get("out").context("--out <path> required")?;
            io::write_edge_list(std::path::Path::new(out), &g.to_coo())?;
            println!("wrote {name} analog ({} vertices, {} edges) to {out}", g.num_vertices, g.num_edges());
            Ok(())
        }
        Some("convert") => {
            let cfg = build_config(&p)?;
            let out = p.get("out").context("--out <path.gsr> required")?;
            let codec: Codec =
                p.get_or("codec", "varint").parse().map_err(anyhow::Error::msg)?;
            // --spill-dir switches to the out-of-core build: bounded
            // sorted batches spill to runs, a k-way merge streams the
            // final edge order straight into .gsr emission, and the
            // output is byte-identical to the in-memory path below.
            if !cfg.storage_spill_dir.is_empty() {
                let input = p
                    .get("graph")
                    .context("--spill-dir converts a file on disk: pass --graph <edge-list|.mtx>")?;
                let scfg = gunrock::graph::builder::SpillConfig {
                    spill_dir: cfg.storage_spill_dir.clone().into(),
                    batch_edges: cfg.storage_batch_edges,
                    undirected: p.get_bool("undirected"),
                    weighted: p.get_bool("weighted"),
                    weight_seed: 42,
                    codec,
                    with_in_edges: !p.get_bool("no-in-edges"),
                };
                let stats = gunrock::graph::builder::build_gsr_out_of_core(
                    std::path::Path::new(input),
                    std::path::Path::new(out),
                    &scfg,
                )?;
                println!(
                    "wrote {input} ({} vertices, {} edges, {codec}) to {out}\n  \
                     out-of-core: {} edge records spilled across {} sorted runs \
                     (batch budget {} edges)",
                    stats.num_vertices,
                    stats.final_edges,
                    stats.spilled_records,
                    stats.runs,
                    cfg.storage_batch_edges,
                );
                return Ok(());
            }
            let (name, g) = load_graph(&p, p.get_bool("weighted"))?;
            // The in-edge view is on by default: it is what lets
            // direction-optimized BFS and pull PageRank traverse the
            // container compressed-natively. --no-in-edges writes the
            // leaner push-only layout.
            let cg = if p.get_bool("no-in-edges") {
                CompressedCsr::from_csr(&g, codec)
            } else {
                CompressedCsr::from_csr_with_in_edges(&g, codec)
            };
            io::save_gsr(std::path::Path::new(out), &cg)?;
            let raw = raw_csr_bytes(g.num_vertices, g.num_edges());
            println!(
                "wrote {name} ({} vertices, {} edges, {codec}) to {out}\n  \
                 adjacency: {:.2} B/edge compressed vs {:.2} B/edge raw CSR ({:.0}%){}",
                g.num_vertices,
                g.num_edges(),
                cg.bytes_per_edge(),
                raw as f64 / g.num_edges().max(1) as f64,
                100.0 * cg.total_bytes() as f64 / raw.max(1) as f64,
                if cg.has_in_view() {
                    format!(
                        "\n  in-edge view: {:.2} B/edge (pull/direction-optimized traversal)",
                        cg.in_view_bytes() as f64 / g.num_edges().max(1) as f64
                    )
                } else {
                    String::new()
                },
            );
            Ok(())
        }
        Some("stats") => {
            let (name, g) = load_graph(&p, false)?;
            let raw = raw_csr_bytes(g.num_vertices, g.num_edges());
            let raw_bpe = raw as f64 / g.num_edges().max(1) as f64;
            let mut rows = vec![vec![
                "raw CSR".to_string(),
                format!("{raw_bpe:.2}"),
                format!("{:.2}", raw_bpe * 8.0),
                "100%".to_string(),
            ]];
            for codec in
                [Codec::Varint, Codec::Zeta(1), Codec::Zeta(2), Codec::Zeta(3), Codec::Zeta(4)]
            {
                let cg = CompressedCsr::from_csr(&g, codec);
                rows.push(vec![
                    codec.to_string(),
                    format!("{:.2}", cg.bytes_per_edge()),
                    format!("{:.2}", cg.payload_bits_per_edge()),
                    format!("{:.0}%", 100.0 * cg.total_bytes() as f64 / raw.max(1) as f64),
                ]);
            }
            harness::print_table(
                &format!(
                    "Storage: {name} ({} vertices, {} edges)",
                    g.num_vertices,
                    g.num_edges()
                ),
                &["codec", "B/edge (incl. index)", "payload bits/edge", "vs raw"],
                &rows,
            );
            Ok(())
        }
        Some("run") => {
            let prim = p.positionals.first().context("run <primitive>")?.clone();
            let kind: PrimitiveKind = prim.parse::<PrimitiveKind>()?;
            let cfg = build_config(&p)?;
            let weighted = kind.needs_weights();
            // Every primitive is generic over GraphRep: a `.gsr` graph is
            // traversed compressed-natively (decode-on-advance, no
            // decompress-to-CSR fallback), anything else goes through raw
            // CSR. The two arms call the same generic runner.
            match p.get("graph") {
                Some(path) if path.ends_with(".gsr") => {
                    let mut cg = load_gsr_cfg(std::path::Path::new(path), &cfg)?;
                    let m = cg.num_edges();
                    ensure_uniform_weights(&mut cg.edge_weights, m, weighted);
                    println!(
                        "{} on {path} [compressed {}, {:.2} B/edge{}{}]: \
                         {} vertices, {} edges, {} threads",
                        kind,
                        cg.codec,
                        cg.bytes_per_edge(),
                        if cg.has_in_view() { ", in-edge view" } else { ", push-only" },
                        if cfg.storage_mmap { ", mapped" } else { "" },
                        cg.num_vertices,
                        cg.num_edges(),
                        cfg.effective_threads()
                    );
                    run_primitive(kind, &cg, &cfg, &p)
                }
                _ => {
                    let (name, g) = load_graph(&p, weighted)?;
                    println!(
                        "{} on {name}: {} vertices, {} edges, {} threads",
                        kind,
                        g.num_vertices,
                        g.num_edges(),
                        cfg.effective_threads()
                    );
                    run_primitive(kind, &g, &cfg, &p)
                }
            }
        }
        Some("serve") => {
            let cfg = build_config(&p)?;
            // Load weighted so distance queries work out of the box (the
            // weights are the paper's deterministic uniform [1, 64]).
            match p.get("graph") {
                Some(path) if path.ends_with(".gsr") => {
                    let mut cg = load_gsr_cfg(std::path::Path::new(path), &cfg)?;
                    let m = cg.num_edges();
                    ensure_uniform_weights(&mut cg.edge_weights, m, true);
                    println!(
                        "serving {path}{} [compressed {}]: {} vertices, {} edges",
                        if cfg.storage_mmap { " (mapped)" } else { "" },
                        cg.codec,
                        cg.num_vertices,
                        cg.num_edges()
                    );
                    serve(std::sync::Arc::new(cg), cfg, &p)
                }
                _ => {
                    let (name, g) = load_graph(&p, true)?;
                    println!(
                        "serving {name}: {} vertices, {} edges",
                        g.num_vertices,
                        g.num_edges()
                    );
                    serve(std::sync::Arc::new(g), cfg, &p)
                }
            }
        }
        Some(other) => {
            usage();
            bail!("unknown subcommand {other}");
        }
    }
}

/// Run one primitive over any graph representation (raw CSR or the
/// compressed `.gsr` payload) through the unified request surface — the
/// per-primitive logic below is purely presentational.
fn run_primitive<G: GraphRep>(
    kind: PrimitiveKind,
    g: &G,
    cfg: &Config,
    p: &cli::ParsedArgs,
) -> Result<()> {
    if kind == PrimitiveKind::Bfs && cfg.direction_optimized && !g.has_in_edges() {
        eprintln!(
            "warning: --direction-optimized ignored: this graph has no in-edge \
             view (re-convert with in-edges for pull traversal), traversing push-only"
        );
    }
    let mut req = Request::new(kind);
    if let Some(s) = p.get_parse::<u32>("src")? {
        req.sources = vec![s];
    }
    req.params.pull = p.get_bool("pull");
    let resp = api::run_request(g, &req, cfg)?;
    describe(&resp);
    if let Some(s) = resp.iterations {
        println!(
            "  frontier: max={} push_iters={} pull_iters={} edges={}",
            s.max_frontier, s.push, s.pull, s.edges
        );
    }
    finish_trace(cfg)?;
    Ok(())
}

/// Render a response: one summary line per primitive, same fields the
/// pre-API CLI printed.
fn describe(resp: &api::Response) {
    let src = resp.source.unwrap_or(0);
    match &resp.output {
        Output::Bfs { labels, push_iterations, pull_iterations, .. } => {
            let reached = labels.iter().filter(|&&d| d != bfs::INFINITY_DEPTH).count();
            let depth_max = labels.iter().filter(|&&d| d != bfs::INFINITY_DEPTH).max();
            report(
                &resp.run,
                &format!(
                    "src={src} reached={reached} depth_max={} push_iters={push_iterations} pull_iters={pull_iterations}",
                    depth_max.unwrap_or(&0),
                ),
            );
        }
        Output::Sssp { dist, .. } => {
            let reached = dist.iter().filter(|&&d| d < sssp::INFINITY_DIST).count();
            report(&resp.run, &format!("src={src} reached={reached}"));
        }
        Output::Bc { .. } => report(&resp.run, &format!("src={src}")),
        Output::PageRank { ranks, iterations } => {
            let top: Vec<usize> = top_k(ranks, 5);
            report(&resp.run, &format!("iters={iterations} top5={top:?}"));
        }
        Output::Cc { num_components, .. } => {
            report(&resp.run, &format!("components={num_components}"));
        }
        Output::Tc { triangles } => report(&resp.run, &format!("triangles={triangles}")),
        Output::Wtf { recommendations, .. } => {
            report(&resp.run, &format!("user={src} recs={recommendations:?}"));
        }
        Output::Ppr { recommendations, .. } => {
            report(&resp.run, &format!("user={src} recs={recommendations:?}"));
        }
        Output::Mst { tree_edges, total_weight } => {
            report(&resp.run, &format!("forest_edges={tree_edges} weight={total_weight}"));
        }
        Output::Color { num_colors } => report(&resp.run, &format!("colors={num_colors}")),
        Output::Mis { size } => report(&resp.run, &format!("independent={size}")),
        Output::Lp { num_communities, iterations } => {
            report(&resp.run, &format!("communities={num_communities} iters={iterations}"));
        }
        Output::Radii { radius, eccentricities } => {
            println!("  pseudo-radius {radius} from samples {eccentricities:?}");
        }
    }
}

/// The `serve` loop: `--demo <n>` self-drives with synthetic queries;
/// otherwise read the line protocol from stdin.
fn serve<G: GraphRep + Send + Sync + 'static>(
    g: std::sync::Arc<G>,
    cfg: Config,
    p: &cli::ParsedArgs,
) -> Result<()> {
    let n = g.num_vertices() as u32;
    if n == 0 {
        bail!(QueryError::Malformed("empty graph".to_string()));
    }
    let weighted = g.is_weighted();
    let seed = cfg.seed;
    let trace_cfg = cfg.clone();
    let svc = QueryService::start(g, cfg);

    if let Some(count) = p.get_parse::<usize>("demo")? {
        // Mixed synthetic workload from a local xorshift: hop/distance
        // point queries over a reused source pool (so batching and the
        // landmark cache both engage) plus a PPR sprinkle.
        let mut state = seed | 1;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let pool: Vec<u32> = (0..128).map(|_| (rng() % n as u64) as u32).collect();
        let t = gunrock::util::timer::Timer::start();
        let mut answered = 0usize;
        let mut unreachable = 0usize;
        let mut errored = 0usize;
        for i in 0..count {
            let src = pool[(rng() % pool.len() as u64) as usize];
            let dst = (rng() % n as u64) as u32;
            let q = match i % 3 {
                0 => Query::bfs(src, dst),
                1 if weighted => Query::sssp(src, dst),
                _ => Query::ppr(src),
            };
            // Typed errors (shed, deadline, resource-exhausted, injected
            // faults) are the service doing its job under pressure — the
            // demo counts them instead of aborting, so soak runs under a
            // tight --mem-budget exercise the ladder end to end.
            match svc.submit(q) {
                Ok(Answer::Hops(None)) | Ok(Answer::Distance(None)) => unreachable += 1,
                Ok(_) => {}
                Err(_) => errored += 1,
            }
            answered += 1;
        }
        let ms = t.elapsed_ms();
        let s = svc.stats();
        println!(
            "demo: {answered} queries in {ms:.1} ms ({:.0} q/s), {unreachable} unreachable, \
             {errored} typed errors",
            answered as f64 / (ms / 1000.0).max(1e-9)
        );
        println!("health: {}", svc.health_json());
        println!(
            "stats: submitted={} served={} batches={} cache_hits={} coalesced={} \
             rejected={} shed={} retries={} batcher_restarts={}",
            s.submitted,
            s.served,
            s.batches,
            s.cache_hits,
            s.coalesced,
            s.rejected,
            s.shed,
            s.retries,
            s.batcher_restarts
        );
        finish_trace(&trace_cfg)?;
        return Ok(());
    }

    println!(
        "ready (bfs <src> <dst> | sssp <src> <dst> | ppr <user> | stats | metrics | \
         health | quit)"
    );
    // The protocol loop lives in service::protocol so its resilience
    // (malformed lines, oversized lines, garbage bytes) is unit-tested;
    // this is the only stdin/stdout binding.
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let stats = protocol::serve_loop(&svc, &mut stdin.lock(), &mut stdout.lock())?;
    if stats.malformed_requests > 0 {
        eprintln!("note: {} malformed request line(s) ignored", stats.malformed_requests);
    }
    finish_trace(&trace_cfg)?;
    Ok(())
}

fn top_k(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    // total_cmp: a NaN rank (shouldn't happen, but data is data) sorts
    // deterministically instead of panicking the report path.
    idx.sort_unstable_by(|&a, &b| xs[b].total_cmp(&xs[a]));
    idx.truncate(k);
    idx
}

fn report(r: &gunrock::enactor::RunResult, extra: &str) {
    println!(
        "  runtime {:.3} ms | {:.1} MTEPS | {} iterations | warp efficiency {:.2}% | {extra}",
        r.runtime_ms,
        r.mteps(),
        r.num_iterations(),
        r.warp_efficiency * 100.0
    );
}
