//! gunrock CLI — the L3 coordinator entry point.
//!
//! Subcommands:
//!   run <primitive>    run a primitive on a dataset analog or graph file
//!   generate           emit a synthetic dataset to an edge-list file
//!   convert            compress a graph into the .gsr container
//!   stats              report bits/edge for every codec on a graph
//!   info               print dataset topology properties (Table 4 columns)
//!   offload <what>     run PageRank / pull-BFS through the AOT XLA artifact
//!   datasets           list registered paper-dataset analogs
//!
//! Examples:
//!   gunrock run bfs --dataset soc-orkut --direction-optimized
//!   gunrock run sssp --dataset roadnet_USA --strategy twc
//!   gunrock convert --dataset rmat_s22_e64 --codec zeta2 --out /tmp/rmat.gsr
//!   gunrock run bfs --graph /tmp/rmat.gsr          # decode-on-advance
//!   gunrock stats --dataset soc-orkut
//!   gunrock offload pagerank --dataset kron_g500-logn10
//!   gunrock generate --dataset rmat_s22_e64 --out /tmp/rmat.txt

use anyhow::{bail, Context, Result};

use gunrock::config::{cli, Config};
use gunrock::graph::compressed::{raw_csr_bytes, Codec, CompressedCsr};
use gunrock::graph::{datasets, io, properties, GraphRep};
use gunrock::harness::{self, suite};
use gunrock::primitives::{
    bfs, cc, color, label_propagation, mst, pagerank, sssp, tc, traversal_extras, wtf,
};

const BOOL_FLAGS: &[&str] =
    &["direction-optimized", "idempotence", "weighted", "undirected", "pull", "no-in-edges"];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "gunrock — Gunrock: GPU Graph Analytics (TOPC 2017), CPU-simulated reproduction\n\
         \n\
         USAGE: gunrock <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS\n\
           run <bfs|sssp|bc|pagerank|cc|tc|wtf|mst|color|mis|lp|radii>\n\
                                                  run a primitive (every primitive\n\
                                                  traverses .gsr compressed-natively)\n\
           convert                                compress to .gsr (--out, --codec;\n\
                                                  in-edge view by default)\n\
           stats                                  bits/edge per codec for a graph\n\
           offload <pagerank|bfs>                 run through the AOT XLA artifact\n\
           info                                   dataset topology properties\n\
           generate                               write a dataset analog to a file\n\
           datasets                               list paper-dataset analogs\n\
         \n\
         COMMON FLAGS\n\
           --dataset <name>      paper dataset analog (see `gunrock datasets`)\n\
           --graph <path>        load .mtx, .gsr, or edge-list file instead\n\
           --codec <c>           .gsr gap codec: varint (default) | zeta1..zeta8\n\
           --no-in-edges          convert: skip the .gsr v2 in-edge section\n\
           --out <path>          output path (convert, generate)\n\
           --config <path>       TOML config file\n\
           --threads <n>         worker threads (default: all cores)\n\
           --pool-threads <n>    persistent pool width (default: --threads)\n\
           --strategy <s>        ThreadExpand|TWC|LB|LB_LIGHT|LB_CULL (default auto)\n\
           --src <v>             source vertex (default: max-degree vertex)\n\
           --direction-optimized  enable push/pull switching (BFS)\n\
           --idempotence          enable idempotent advance (BFS)\n\
           --pull                 pagerank: pull-mode gather (needs in-edge view)\n\
           --do-a <f> --do-b <f>  direction heuristic parameters\n\
           --delta <n>            SSSP near/far delta (0 = Bellman-Ford)\n\
           --frontier-switch <f>  hybrid frontier densify threshold as a\n\
                                  fraction of m (default 0.05)\n\
           --frontier-mode <m>    frontier representation: auto (default)\n\
                                  | sparse | dense\n"
    );
}

fn build_config(p: &cli::ParsedArgs) -> Result<Config> {
    let mut cfg = match p.get("config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    if let Some(t) = p.get_parse::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(t) = p.get_parse::<usize>("pool-threads")? {
        cfg.pool_threads = t;
    }
    if let Some(s) = p.get("strategy") {
        cfg.strategy = Some(s.parse().map_err(anyhow::Error::msg)?);
    }
    if p.get_bool("direction-optimized") {
        cfg.direction_optimized = true;
    }
    if p.get_bool("idempotence") {
        cfg.idempotence = true;
    }
    if let Some(v) = p.get_parse::<f64>("do-a")? {
        cfg.do_a = v;
    }
    if let Some(v) = p.get_parse::<f64>("do-b")? {
        cfg.do_b = v;
    }
    if let Some(v) = p.get_parse::<u64>("delta")? {
        cfg.sssp_delta = v;
    }
    if let Some(v) = p.get_parse::<f64>("frontier-switch")? {
        cfg.frontier_switch = v;
    }
    if let Some(s) = p.get("frontier-mode") {
        cfg.frontier_mode = s.parse().map_err(anyhow::Error::msg)?;
    }
    if let Some(v) = p.get("artifacts-dir") {
        cfg.artifacts_dir = v.to_string();
    }
    Ok(cfg)
}

/// SSSP/MST need weights. When the source (file, dataset analog — some,
/// like the WTF follow graphs, ignore the `weighted` request — or `.gsr`
/// container) provides none, attach the deterministic positional array:
/// one seed, one code path, so every representation of the same graph
/// gets the identical weights and runs stay bit-comparable across them.
fn ensure_uniform_weights(
    weights: &mut Vec<gunrock::graph::Weight>,
    num_edges: usize,
    weighted: bool,
) {
    if weighted && weights.is_empty() {
        *weights = datasets::uniform_weights(num_edges, 42);
    }
}

fn load_graph(p: &cli::ParsedArgs, weighted: bool) -> Result<(String, gunrock::graph::Csr)> {
    let (name, mut g) = if let Some(path) = p.get("graph") {
        let g = io::load_graph(std::path::Path::new(path), p.get_bool("undirected"))?;
        (path.to_string(), g)
    } else {
        let name = p.get_or("dataset", "rmat_s22_e64").to_string();
        let g = datasets::load(&name, weighted);
        (name, g)
    };
    let m = g.num_edges();
    ensure_uniform_weights(&mut g.edge_weights, m, weighted);
    Ok((name, g))
}

fn run(args: &[String]) -> Result<()> {
    let p = cli::parse(args, BOOL_FLAGS)?;
    match p.subcommand.as_deref() {
        None | Some("help") | Some("--help") => {
            usage();
            Ok(())
        }
        Some("datasets") => {
            println!("paper dataset -> analog (see graph::datasets)");
            for name in datasets::TABLE4 {
                let spec = datasets::spec(name);
                println!("  {:18} {:?}: {}", name, spec.class, spec.description);
            }
            for name in datasets::WTF_DATASETS {
                let spec = datasets::spec(name);
                println!("  {:18} {:?}: {}", name, spec.class, spec.description);
            }
            Ok(())
        }
        Some("info") => {
            let (name, g) = load_graph(&p, false)?;
            let props = properties::analyze(&g);
            println!("dataset: {name}");
            println!("  vertices:        {}", props.vertices);
            println!("  edges:           {}", props.edges);
            println!("  max degree:      {}", props.max_degree);
            println!("  avg degree:      {:.2}", props.avg_degree);
            println!("  degree stddev:   {:.2}", props.degree_stddev);
            println!("  pseudo-diameter: {}", props.pseudo_diameter);
            println!("  deg<64 fraction: {:.2}", props.frac_low_degree);
            println!("  class:           {}", if props.is_scale_free() { "scale-free" } else { "mesh-like" });
            Ok(())
        }
        Some("generate") => {
            let (name, g) = load_graph(&p, p.get_bool("weighted"))?;
            let out = p.get("out").context("--out <path> required")?;
            io::write_edge_list(std::path::Path::new(out), &g.to_coo())?;
            println!("wrote {name} analog ({} vertices, {} edges) to {out}", g.num_vertices, g.num_edges());
            Ok(())
        }
        Some("convert") => {
            let (name, g) = load_graph(&p, p.get_bool("weighted"))?;
            let out = p.get("out").context("--out <path.gsr> required")?;
            let codec: Codec =
                p.get_or("codec", "varint").parse().map_err(anyhow::Error::msg)?;
            // The in-edge view is on by default: it is what lets
            // direction-optimized BFS and pull PageRank traverse the
            // container compressed-natively. --no-in-edges writes the
            // leaner push-only layout.
            let cg = if p.get_bool("no-in-edges") {
                CompressedCsr::from_csr(&g, codec)
            } else {
                CompressedCsr::from_csr_with_in_edges(&g, codec)
            };
            io::save_gsr(std::path::Path::new(out), &cg)?;
            let raw = raw_csr_bytes(g.num_vertices, g.num_edges());
            println!(
                "wrote {name} ({} vertices, {} edges, {codec}) to {out}\n  \
                 adjacency: {:.2} B/edge compressed vs {:.2} B/edge raw CSR ({:.0}%){}",
                g.num_vertices,
                g.num_edges(),
                cg.bytes_per_edge(),
                raw as f64 / g.num_edges().max(1) as f64,
                100.0 * cg.total_bytes() as f64 / raw.max(1) as f64,
                if cg.has_in_view() {
                    format!(
                        "\n  in-edge view: {:.2} B/edge (pull/direction-optimized traversal)",
                        cg.in_view_bytes() as f64 / g.num_edges().max(1) as f64
                    )
                } else {
                    String::new()
                },
            );
            Ok(())
        }
        Some("stats") => {
            let (name, g) = load_graph(&p, false)?;
            let raw = raw_csr_bytes(g.num_vertices, g.num_edges());
            let raw_bpe = raw as f64 / g.num_edges().max(1) as f64;
            let mut rows = vec![vec![
                "raw CSR".to_string(),
                format!("{raw_bpe:.2}"),
                format!("{:.2}", raw_bpe * 8.0),
                "100%".to_string(),
            ]];
            for codec in
                [Codec::Varint, Codec::Zeta(1), Codec::Zeta(2), Codec::Zeta(3), Codec::Zeta(4)]
            {
                let cg = CompressedCsr::from_csr(&g, codec);
                rows.push(vec![
                    codec.to_string(),
                    format!("{:.2}", cg.bytes_per_edge()),
                    format!("{:.2}", cg.payload_bits_per_edge()),
                    format!("{:.0}%", 100.0 * cg.total_bytes() as f64 / raw.max(1) as f64),
                ]);
            }
            harness::print_table(
                &format!(
                    "Storage: {name} ({} vertices, {} edges)",
                    g.num_vertices,
                    g.num_edges()
                ),
                &["codec", "B/edge (incl. index)", "payload bits/edge", "vs raw"],
                &rows,
            );
            Ok(())
        }
        Some("run") => {
            let prim = p.positionals.first().context("run <primitive>")?.clone();
            let cfg = build_config(&p)?;
            let weighted = matches!(prim.as_str(), "sssp" | "mst");
            // Every primitive is generic over GraphRep: a `.gsr` graph is
            // traversed compressed-natively (decode-on-advance, no
            // decompress-to-CSR fallback), anything else goes through raw
            // CSR. The two arms call the same generic runner.
            match p.get("graph") {
                Some(path) if path.ends_with(".gsr") => {
                    let mut cg = io::load_gsr(std::path::Path::new(path))?;
                    let m = cg.num_edges();
                    ensure_uniform_weights(&mut cg.edge_weights, m, weighted);
                    println!(
                        "{} on {path} [compressed {}, {:.2} B/edge{}]: \
                         {} vertices, {} edges, {} threads",
                        prim,
                        cg.codec,
                        cg.bytes_per_edge(),
                        if cg.has_in_view() { ", in-edge view" } else { ", push-only" },
                        cg.num_vertices,
                        cg.num_edges(),
                        cfg.effective_threads()
                    );
                    run_primitive(&prim, &cg, &cfg, &p)
                }
                _ => {
                    let (name, g) = load_graph(&p, weighted)?;
                    println!(
                        "{} on {name}: {} vertices, {} edges, {} threads",
                        prim,
                        g.num_vertices,
                        g.num_edges(),
                        cfg.effective_threads()
                    );
                    run_primitive(&prim, &g, &cfg, &p)
                }
            }
        }
        Some("offload") => {
            let what = p.positionals.first().context("offload <pagerank|bfs>")?.clone();
            let cfg = build_config(&p)?;
            // AOT artifacts exist at n in {1024, 4096}; default to a graph
            // that fits the small variant.
            let name = p.get_or("dataset", "grid_1k").to_string();
            let g = datasets::load(&name, false);
            let mut rt = gunrock::runtime::XlaRuntime::new(std::path::Path::new(&cfg.artifacts_dir))?;
            println!("PJRT platform: {}", rt.platform());
            match what.as_str() {
                "pagerank" | "pr" => {
                    let t = gunrock::util::timer::Timer::start();
                    let (ranks, iters) = rt.pagerank(&g, 1e-6, 50)?;
                    println!(
                        "XLA PageRank on {name}: {} vertices, {iters} iterations, {:.2} ms, top5={:?}",
                        g.num_vertices, t.elapsed_ms(),
                        top_k(&ranks.iter().map(|&x| x as f64).collect::<Vec<_>>(), 5)
                    );
                }
                "bfs" => {
                    let src = p.get_parse::<u32>("src")?.unwrap_or_else(|| suite::pick_source(&g));
                    let t = gunrock::util::timer::Timer::start();
                    let (depth, iters) = rt.bfs_pull(&g, src, 1000)?;
                    let reached = depth.iter().filter(|&&d| d != u32::MAX).count();
                    println!(
                        "XLA pull-BFS on {name}: src={src} reached={reached} iters={iters} {:.2} ms",
                        t.elapsed_ms()
                    );
                }
                other => bail!("unknown offload target {other}"),
            }
            Ok(())
        }
        Some(other) => {
            usage();
            bail!("unknown subcommand {other}");
        }
    }
}

/// Run one primitive over any graph representation (raw CSR or the
/// compressed `.gsr` payload) — the whole suite is generic over
/// [`GraphRep`], so there is no per-representation dispatch below this
/// point.
fn run_primitive<G: GraphRep>(
    prim: &str,
    g: &G,
    cfg: &Config,
    p: &cli::ParsedArgs,
) -> Result<()> {
    let src = match p.get_parse::<u32>("src")? {
        Some(s) => s,
        None => suite::pick_source(g),
    };
    match prim {
        "bfs" => {
            if cfg.direction_optimized && !g.has_in_edges() {
                eprintln!(
                    "warning: --direction-optimized ignored: this graph has no in-edge \
                     view (re-convert with in-edges for pull traversal), traversing push-only"
                );
            }
            let (prob, st) = bfs::bfs(g, src, cfg);
            let reached = prob.labels.iter().filter(|&&d| d != bfs::INFINITY_DEPTH).count();
            report(
                &st.result,
                &format!(
                    "src={src} reached={reached} depth_max={} push_iters={} pull_iters={}",
                    prob.labels.iter().filter(|&&d| d != bfs::INFINITY_DEPTH).max().unwrap_or(&0),
                    st.push_iterations,
                    st.pull_iterations
                ),
            );
        }
        "sssp" => {
            let (prob, r) = sssp::sssp(g, src, cfg);
            let reached = prob.dist.iter().filter(|&&d| d < sssp::INFINITY_DIST).count();
            report(&r, &format!("src={src} reached={reached}"));
        }
        "bc" => {
            let (_, r) = gunrock::primitives::bc::bc_from_source(g, src, cfg);
            report(&r, &format!("src={src}"));
        }
        "pagerank" | "pr" => {
            if p.get_bool("pull") {
                if !g.has_in_edges() {
                    bail!("--pull requires an in-edge view (re-convert with in-edges)");
                }
                let (prob, r) = pagerank::pagerank_pull(g, cfg);
                let top: Vec<usize> = top_k(&prob.ranks, 5);
                report(&r, &format!("mode=pull iters={} top5={top:?}", prob.iterations));
            } else {
                let (prob, r) = pagerank::pagerank(g, cfg);
                let top: Vec<usize> = top_k(&prob.ranks, 5);
                report(&r, &format!("iters={} top5={top:?}", prob.iterations));
            }
        }
        "cc" => {
            let (prob, r) = cc::cc(g, cfg);
            report(&r, &format!("components={}", prob.num_components));
        }
        "tc" => {
            let (res, r) = tc::tc_intersect_filtered(g, cfg);
            report(&r, &format!("triangles={}", res.triangles));
        }
        "wtf" => {
            let (res, r) = wtf::wtf(g, src, 100, 10, cfg);
            report(
                &r,
                &format!(
                    "user={src} recs={:?} (ppr {:.2}ms, cot {:.2}ms, money {:.2}ms)",
                    res.recommendations, res.ppr_ms, res.cot_ms, res.money_ms
                ),
            );
        }
        "mst" => {
            // The loaders attach uniform weights for mst up front.
            let (res, r) = mst::mst(g, cfg);
            report(
                &r,
                &format!("forest_edges={} weight={}", res.tree_edges.len(), res.total_weight),
            );
        }
        "color" => {
            let (res, r) = color::color(g, cfg);
            report(&r, &format!("colors={}", res.num_colors));
        }
        "mis" => {
            let (in_mis, r) = color::mis(g, cfg);
            report(&r, &format!("independent={}", in_mis.iter().filter(|&&b| b).count()));
        }
        "lp" | "label-propagation" => {
            let (res, r) = label_propagation::label_propagation(g, cfg);
            report(&r, &format!("communities={} iters={}", res.num_communities, res.iterations));
        }
        "radii" => {
            let (radius, eccs) = traversal_extras::estimate_radius(g, 8, cfg, cfg.seed);
            println!("  pseudo-radius {radius} from samples {eccs:?}");
        }
        other => bail!("unknown primitive {other}"),
    }
    Ok(())
}

fn top_k(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_unstable_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx.truncate(k);
    idx
}

fn report(r: &gunrock::enactor::RunResult, extra: &str) {
    println!(
        "  runtime {:.3} ms | {:.1} MTEPS | {} iterations | warp efficiency {:.2}% | {extra}",
        r.runtime_ms,
        r.mteps(),
        r.num_iterations(),
        r.warp_efficiency * 100.0
    );
}
