//! Per-run virtual-warp counters.
//!
//! Strategies in `load_balance` report every virtual warp they retire:
//! `record_warp(active_lanes)` with `active_lanes <= WARP_WIDTH`. The
//! resulting warp execution efficiency (active / (warps * width)) is the
//! paper's Table 8 metric. Additional counters track edges, atomics, and
//! kernel launches for the §5 throughput analyses.

use std::sync::atomic::{AtomicU64, Ordering};

use super::WARP_WIDTH;

#[derive(Default)]
pub struct WarpCounters {
    lanes_active: AtomicU64,
    warps_retired: AtomicU64,
    edges_processed: AtomicU64,
    atomics_issued: AtomicU64,
    kernel_launches: AtomicU64,
    filter_culled: AtomicU64,
}

impl WarpCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a retired virtual warp with `active` active lanes.
    #[inline]
    pub fn record_warp(&self, active: usize) {
        debug_assert!(active <= WARP_WIDTH);
        self.lanes_active.fetch_add(active as u64, Ordering::Relaxed);
        self.warps_retired.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` full warps plus the ragged tail over `items` lanes of
    /// work — convenience for strategies that process contiguous runs.
    #[inline]
    pub fn record_run(&self, items: usize) {
        let full = items / WARP_WIDTH;
        let tail = items % WARP_WIDTH;
        if full > 0 {
            self.lanes_active.fetch_add((full * WARP_WIDTH) as u64, Ordering::Relaxed);
            self.warps_retired.fetch_add(full as u64, Ordering::Relaxed);
        }
        if tail > 0 {
            self.record_warp(tail);
        }
    }

    /// Record a SIMD-lockstep group directly: `warps` warp-issues carrying
    /// `active` active lanes in total. Used by strategies that model a
    /// 32-item group running in lockstep for max(deg) steps — e.g.
    /// ThreadExpand, where each lane serially walks its own neighbor list
    /// and short lists idle while the longest in the warp finishes.
    #[inline]
    pub fn record_simd(&self, active: u64, warps: u64) {
        debug_assert!(active <= warps * WARP_WIDTH as u64);
        self.lanes_active.fetch_add(active, Ordering::Relaxed);
        self.warps_retired.fetch_add(warps, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_edges(&self, n: u64) {
        self.edges_processed.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_atomics(&self, n: u64) {
        self.atomics_issued.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_kernel_launch(&self) {
        self.kernel_launches.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_culled(&self, n: u64) {
        self.filter_culled.fetch_add(n, Ordering::Relaxed);
    }

    pub fn edges(&self) -> u64 {
        self.edges_processed.load(Ordering::Relaxed)
    }

    pub fn atomics(&self) -> u64 {
        self.atomics_issued.load(Ordering::Relaxed)
    }

    pub fn launches(&self) -> u64 {
        self.kernel_launches.load(Ordering::Relaxed)
    }

    pub fn culled(&self) -> u64 {
        self.filter_culled.load(Ordering::Relaxed)
    }

    pub fn warps(&self) -> u64 {
        self.warps_retired.load(Ordering::Relaxed)
    }

    /// Paper Table 8: "fraction of threads active during computation".
    pub fn warp_efficiency(&self) -> f64 {
        let warps = self.warps_retired.load(Ordering::Relaxed);
        if warps == 0 {
            return 1.0;
        }
        let active = self.lanes_active.load(Ordering::Relaxed);
        active as f64 / (warps * WARP_WIDTH as u64) as f64
    }

    pub fn reset(&self) {
        self.lanes_active.store(0, Ordering::Relaxed);
        self.warps_retired.store(0, Ordering::Relaxed);
        self.edges_processed.store(0, Ordering::Relaxed);
        self.atomics_issued.store(0, Ordering::Relaxed);
        self.kernel_launches.store(0, Ordering::Relaxed);
        self.filter_culled.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_full_warps() {
        let c = WarpCounters::new();
        c.record_warp(32);
        c.record_warp(32);
        assert_eq!(c.warp_efficiency(), 1.0);
    }

    #[test]
    fn efficiency_half() {
        let c = WarpCounters::new();
        c.record_warp(16);
        assert_eq!(c.warp_efficiency(), 0.5);
    }

    #[test]
    fn record_run_splits_tail() {
        let c = WarpCounters::new();
        c.record_run(70); // 2 full warps + 6-lane tail
        assert_eq!(c.warps(), 3);
        let eff = c.warp_efficiency();
        assert!((eff - 70.0 / 96.0).abs() < 1e-12);
    }

    #[test]
    fn reset_zeroes() {
        let c = WarpCounters::new();
        c.record_warp(10);
        c.add_edges(5);
        c.add_atomics(2);
        c.reset();
        assert_eq!(c.edges(), 0);
        assert_eq!(c.warps(), 0);
        assert_eq!(c.warp_efficiency(), 1.0);
    }

    #[test]
    fn empty_counters_are_perfect() {
        assert_eq!(WarpCounters::new().warp_efficiency(), 1.0);
    }
}
