//! Virtual-GPU execution model.
//!
//! The paper's load-balancing contribution is defined in terms of CUDA
//! scheduling units: 32-lane SIMD warps, thread blocks (CTAs), and grids.
//! This environment has no GPU, so the strategies in `load_balance` run on
//! a CPU worker pool but *schedule exactly as the paper describes* —
//! work is grouped into virtual warps and blocks, and per-lane activity is
//! counted. That gives us:
//!
//! - the paper's **warp execution efficiency** metric (Table 8): fraction
//!   of lanes active during computation, a direct measure of
//!   load-balancing quality;
//! - a **device cost model** (Fig 18): runtime estimated from memory
//!   traffic / bandwidth for the four Tesla boards in the paper, letting
//!   the bench reproduce the cross-GPU scaling *shape*.

pub mod stats;

pub use stats::WarpCounters;

/// CUDA-like scheduling constants used by the virtual warp model.
pub const WARP_WIDTH: usize = 32;
pub const BLOCK_THREADS: usize = 256;

/// Parameters of a simulated device (paper Fig 18 boards).
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    pub sm_count: usize,
    /// GB/s global-memory bandwidth — the paper observes "performance
    /// generally scales with memory bandwidth" across these boards.
    pub mem_bandwidth_gbps: f64,
    /// Boost clock in MHz (secondary term in the cost model).
    pub clock_mhz: f64,
}

pub const TESLA_K40M: DeviceModel =
    DeviceModel { name: "Tesla K40m", sm_count: 15, mem_bandwidth_gbps: 288.0, clock_mhz: 745.0 };
pub const TESLA_K80: DeviceModel =
    DeviceModel { name: "Tesla K80", sm_count: 13, mem_bandwidth_gbps: 240.0, clock_mhz: 875.0 };
pub const TESLA_M40: DeviceModel =
    DeviceModel { name: "Tesla M40", sm_count: 24, mem_bandwidth_gbps: 288.0, clock_mhz: 1112.0 };
pub const TESLA_M40_24GB: DeviceModel =
    DeviceModel { name: "Tesla M40 24GB", sm_count: 24, mem_bandwidth_gbps: 288.0, clock_mhz: 1328.5 };
pub const TESLA_P100: DeviceModel =
    DeviceModel { name: "Tesla P100", sm_count: 56, mem_bandwidth_gbps: 732.0, clock_mhz: 1328.0 };

pub const FIG18_DEVICES: &[DeviceModel] =
    &[TESLA_K40M, TESLA_K80, TESLA_M40, TESLA_M40_24GB, TESLA_P100];

impl DeviceModel {
    /// Estimate kernel time (ms) for a traversal touching `edges` edges
    /// and `vertices` vertices at a given warp efficiency.
    ///
    /// Memory-bound model: each edge visit moves ~16 bytes (column index,
    /// status probe, frontier write amortized), each vertex ~8; divergence
    /// inflates traffic by 1/efficiency; a per-kernel-launch overhead of
    /// ~5us (paper §5.3 targets exactly this overhead) adds a constant.
    pub fn estimate_traversal_ms(
        &self,
        edges: u64,
        vertices: u64,
        warp_efficiency: f64,
        kernel_launches: u64,
    ) -> f64 {
        let eff = warp_efficiency.clamp(0.05, 1.0);
        let bytes = (edges as f64 * 16.0 + vertices as f64 * 8.0) / eff;
        let mem_ms = bytes / (self.mem_bandwidth_gbps * 1e9) * 1e3;
        let launch_ms = kernel_launches as f64 * 5e-3;
        // Clock term: small-frontier iterations are latency, not bandwidth,
        // bound; scale launch overhead by inverse clock.
        mem_ms + launch_ms * (1000.0 / self.clock_mhz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p100_faster_than_k40m() {
        let k40 = TESLA_K40M.estimate_traversal_ms(1 << 24, 1 << 20, 0.9, 10);
        let p100 = TESLA_P100.estimate_traversal_ms(1 << 24, 1 << 20, 0.9, 10);
        assert!(p100 < k40);
        // bandwidth ratio ~2.54x should dominate
        assert!(k40 / p100 > 1.8, "ratio {}", k40 / p100);
    }

    #[test]
    fn low_efficiency_costs_time() {
        let good = TESLA_K40M.estimate_traversal_ms(1 << 24, 0, 0.95, 1);
        let bad = TESLA_K40M.estimate_traversal_ms(1 << 24, 0, 0.25, 1);
        assert!(bad > 3.0 * good);
    }

    #[test]
    fn launch_overhead_visible_for_tiny_kernels() {
        let few = TESLA_K40M.estimate_traversal_ms(100, 10, 1.0, 1);
        let many = TESLA_K40M.estimate_traversal_ms(100, 10, 1.0, 1000);
        assert!(many > 10.0 * few);
    }
}
