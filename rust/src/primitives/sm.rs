//! Subgraph matching (paper §6.7): filtering-and-joining. The filtering
//! phase prunes candidate vertices by label and degree with the filter
//! operator; the joining phase grows partial embeddings edge-by-edge in
//! query order, verifying adjacency via (sorted) neighbor-list binary
//! search — the paper's "optimized set-intersection"-flavored join.

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::operators::filter;
use crate::util::timer::Timer;

/// Query pattern: labeled vertices + undirected edges. Small (< ~6 nodes),
/// as in the paper's evaluation.
#[derive(Clone, Debug)]
pub struct Query {
    pub labels: Vec<u32>,
    pub edges: Vec<(usize, usize)>,
}

impl Query {
    pub fn triangle(label: u32) -> Query {
        Query { labels: vec![label; 3], edges: vec![(0, 1), (1, 2), (0, 2)] }
    }

    pub fn path3(a: u32, b: u32, c: u32) -> Query {
        Query { labels: vec![a, b, c], edges: vec![(0, 1), (1, 2)] }
    }

    fn degree(&self, q: usize) -> usize {
        self.edges.iter().filter(|&&(a, b)| a == q || b == q).count()
    }
}

pub struct SmResult {
    /// Each embedding maps query vertex i -> data vertex embeddings[k][i].
    pub embeddings: Vec<Vec<VertexId>>,
}

/// Find all embeddings of `q` in `g` (labels on data vertices given by
/// `labels`). Isomorphism semantics: distinct data vertices per embedding.
/// Generic over the graph representation — adjacency checks in the join
/// go through [`GraphRep::contains_edge`] (binary search on CSR, bounded
/// early-exit decode on compressed graphs).
pub fn subgraph_match<G: GraphRep>(
    g: &G,
    labels: &[u32],
    q: &Query,
    config: &Config,
) -> (SmResult, RunResult) {
    assert_eq!(labels.len(), g.num_vertices());
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();
    let t = Timer::start();

    // ---- Filtering phase: candidates per query vertex (label + degree).
    let mut candidates: Vec<Vec<VertexId>> = Vec::with_capacity(q.labels.len());
    for (qi, &ql) in q.labels.iter().enumerate() {
        let qdeg = q.degree(qi);
        let ctx = enactor.ctx();
        let all = Frontier::all_vertices(g.num_vertices());
        let keep = |v: VertexId| labels[v as usize] == ql && g.degree(v) >= qdeg;
        let f = filter::filter(&ctx, &all, &keep);
        candidates.push(f.into_ids());
    }

    // ---- Joining phase: extend partial embeddings in query-vertex order.
    // (Matching order: as given; production systems pick min-candidate
    // order — the bench queries are tiny so ordering hardly matters.)
    let mut partials: Vec<Vec<VertexId>> = candidates[0].iter().map(|&v| vec![v]).collect();
    for qi in 1..q.labels.len() {
        // query edges from qi to already-matched query vertices
        let back_edges: Vec<usize> = q
            .edges
            .iter()
            .filter_map(|&(a, b)| {
                if a == qi && b < qi {
                    Some(b)
                } else if b == qi && a < qi {
                    Some(a)
                } else {
                    None
                }
            })
            .collect();
        let mut next: Vec<Vec<VertexId>> = Vec::new();
        for partial in &partials {
            for &cand in &candidates[qi] {
                if partial.contains(&cand) {
                    continue; // isomorphism: injective mapping
                }
                let ok = back_edges.iter().all(|&bq| g.contains_edge(partial[bq], cand));
                if ok {
                    let mut e = partial.clone();
                    e.push(cand);
                    next.push(e);
                }
            }
        }
        partials = next;
        enactor.counters.add_edges(partials.len() as u64);
        if partials.is_empty() {
            break;
        }
    }

    enactor.record_iteration(candidates[0].len(), partials.len(), t.elapsed_ms(), false);
    let result = enactor.finish_run();
    (SmResult { embeddings: partials }, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder;

    #[test]
    fn triangle_query_finds_all_orientations() {
        // one triangle 0-1-2 plus a dangling vertex
        let g = builder::undirected_from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let labels = vec![7, 7, 7, 9];
        let (r, _) = subgraph_match(&g, &labels, &Query::triangle(7), &Config::default());
        // 3! = 6 automorphic embeddings of one triangle
        assert_eq!(r.embeddings.len(), 6);
    }

    #[test]
    fn labels_prune_candidates() {
        let g = builder::undirected_from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let labels = vec![1, 2, 3, 9];
        let (r, _) = subgraph_match(&g, &labels, &Query::path3(1, 2, 3), &Config::default());
        // only 0(1) - 1(2) - 2(3)? But query path edges are (0,1),(1,2):
        // 0-1 adjacent, 1-2 adjacent. Exactly one embedding.
        assert_eq!(r.embeddings, vec![vec![0, 1, 2]]);
    }

    #[test]
    fn no_match_when_label_absent() {
        let g = builder::undirected_from_edges(3, &[(0, 1), (1, 2)]);
        let labels = vec![1, 1, 1];
        let (r, _) = subgraph_match(&g, &labels, &Query::triangle(2), &Config::default());
        assert!(r.embeddings.is_empty());
    }

    #[test]
    fn degree_filter_prunes() {
        // path graph has no vertex of degree >= 2 except middle; triangle
        // query needs all degree >= 2
        let g = builder::undirected_from_edges(3, &[(0, 1), (1, 2)]);
        let labels = vec![5, 5, 5];
        let (r, _) = subgraph_match(&g, &labels, &Query::triangle(5), &Config::default());
        assert!(r.embeddings.is_empty());
    }
}
