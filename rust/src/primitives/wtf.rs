//! Who-To-Follow (paper §7.5, after Geil et al. [20]): Twitter's
//! recommendation pipeline on a directed follow graph —
//!
//! 1. **PPR**: personalized PageRank from the query user;
//! 2. **CoT**: the "Circle of Trust" — top-K vertices by PPR score;
//! 3. **Money/SALSA**: bipartite link analysis with the CoT as hubs and
//!    everything the CoT follows as authorities; authority scores rank the
//!    final recommendations.
//!
//! All three stages run through Gunrock operators (advance-based scatter /
//! neighborhood gather), demonstrating the 2-hop bipartite traversal the
//! paper highlights.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::lanes::{LaneBits, LANES};
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::operators::advance;
use crate::util::timer::Timer;

pub struct WtfResult {
    pub circle_of_trust: Vec<VertexId>,
    pub recommendations: Vec<VertexId>,
    pub ppr_scores: Vec<f64>,
    pub ppr_ms: f64,
    pub cot_ms: f64,
    pub money_ms: f64,
}

#[inline]
fn atomic_add_f64(slot: &AtomicU64, add: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + add;
        match slot.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Personalized PageRank with restart at `user` (push-mode advance).
pub fn ppr<G: GraphRep>(
    g: &G,
    user: VertexId,
    iters: usize,
    damp: f64,
    enactor: &mut Enactor,
) -> Vec<f64> {
    let n = g.num_vertices();
    let mut scores = vec![0.0f64; n];
    scores[user as usize] = 1.0;
    for _ in 0..iters {
        if !enactor.budget_ok() {
            break;
        }
        let next: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();
        let strategy = enactor.strategy_for(g, n);
        let ctx = enactor.ctx();
        let scores_ref = &scores;
        let scatter = |s: VertexId, d: VertexId, _e: usize| {
            let deg = g.degree(s);
            if deg > 0 {
                atomic_add_f64(&next[d as usize], scores_ref[s as usize] / deg as f64);
            }
            false
        };
        advance::advance(&ctx, g, &Frontier::all_vertices(n), advance::AdvanceType::V2V, strategy, &scatter);
        // dangling mass restarts at the user, like the walk teleporting home
        let dangling: f64 = (0..n as VertexId)
            .filter(|&v| g.degree(v) == 0)
            .map(|v| scores[v as usize])
            .sum();
        for (v, slot) in next.iter().enumerate() {
            let mut x = damp * f64::from_bits(slot.load(Ordering::Relaxed));
            if v == user as usize {
                x += (1.0 - damp) + damp * dangling;
            }
            scores[v] = x;
        }
    }
    scores
}

/// Bit-parallel multi-source personalized PageRank: up to [`LANES`] query
/// users share one lane-word scatter per iteration — the active mask at a
/// vertex is "which walks have mass here", and each edge decode feeds all
/// of them. Returns lane-major score columns (`out[lane][v]`).
///
/// Unlike the integer traversals, PPR parity with per-user [`ppr`] is
/// **approximate** (float accumulation order differs between schedules);
/// rankings and scores agree to tight tolerance, not bit-for-bit.
pub fn multi_source_ppr<G: GraphRep>(
    g: &G,
    users: &[VertexId],
    iters: usize,
    damp: f64,
    enactor: &mut Enactor,
) -> Vec<Vec<f64>> {
    let k = users.len();
    assert!(
        (1..=LANES).contains(&k),
        "multi_source_ppr takes 1..={LANES} users, got {k}"
    );
    let n = g.num_vertices();
    let mut scores: Vec<Vec<f64>> = (0..k).map(|_| vec![0.0f64; n]).collect();
    let mut active = LaneBits::new(n);
    let mut next_active = LaneBits::new(n);
    for (lane, &u) in users.iter().enumerate() {
        scores[lane][u as usize] = 1.0;
        active.merge(u as usize, 1 << lane);
    }
    active.seal();

    for _ in 0..iters {
        if !enactor.budget_ok() {
            break;
        }
        let next: Vec<Vec<AtomicU64>> =
            (0..k).map(|_| (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect()).collect();
        let strategy = enactor.strategy_for(g, active.active_vertices());
        let ctx = enactor.ctx();
        let scores_ref = &scores;
        let next_ref = &next;
        advance::advance_lanes_into(
            &ctx,
            g,
            &active,
            strategy,
            &|s: VertexId, d: VertexId, _e: usize, mask: u64| {
                let deg = g.degree(s);
                if deg == 0 {
                    return 0;
                }
                let inv_deg = 1.0 / deg as f64;
                let mut out = 0u64;
                crate::frontier::lanes::for_each_lane(mask, |lane| {
                    let x = scores_ref[lane][s as usize];
                    if x != 0.0 {
                        atomic_add_f64(&next_ref[lane][d as usize], x * inv_deg);
                        out |= 1 << lane;
                    }
                });
                out
            },
            &mut next_active,
        );
        // Per-lane damp + restart, column-parallel (lanes are disjoint).
        crate::util::par::for_each_mut(&mut scores, ctx.workers, |lane, col| {
            let dangling: f64 = (0..n as VertexId)
                .filter(|&v| g.degree(v) == 0)
                .map(|v| col[v as usize])
                .sum();
            let user = users[lane] as usize;
            for (v, slot) in next[lane].iter().enumerate() {
                let mut x = damp * f64::from_bits(slot.load(Ordering::Relaxed));
                if v == user {
                    x += (1.0 - damp) + damp * dangling;
                }
                col[v] = x;
            }
        });
        // The restart keeps every user's own vertex live even when no
        // mass flowed in; everything else active is exactly the inflow.
        for (lane, &u) in users.iter().enumerate() {
            next_active.merge(u as usize, 1 << lane);
        }
        next_active.seal();
        std::mem::swap(&mut active, &mut next_active);
    }
    scores
}

/// Batched PPR entry point owning its enactor: the engine behind both
/// single-user WTF/PPR requests (one lane) and the query service's
/// recommendation batches. Returns lane-major score columns plus one
/// [`RunResult`] covering the whole batch.
pub fn ppr_batch<G: GraphRep>(
    g: &G,
    users: &[VertexId],
    iters: usize,
    damp: f64,
    config: &Config,
) -> (Vec<Vec<f64>>, RunResult) {
    let _span = crate::obs::span(
        crate::obs::EventKind::PrimitiveRun,
        crate::obs::tags::PPR,
        users.len() as u64,
    );
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();
    let t = Timer::start();
    let cols = multi_source_ppr(g, users, iters, damp, &mut enactor);
    enactor.record_iteration(g.num_vertices(), users.len(), t.elapsed_ms(), false);
    let mut result = enactor.finish_run();
    result.lanes = users.len();
    (cols, result)
}

/// Top-k vertices by score, excluding the user (the Circle of Trust; the
/// original WTF uses K = 1000).
pub fn circle_of_trust(scores: &[f64], user: VertexId, k: usize) -> Vec<VertexId> {
    let mut idx: Vec<VertexId> = (0..scores.len() as VertexId)
        .filter(|&v| v != user && scores[v as usize] > 0.0)
        .collect();
    idx.sort_unstable_by(|&a, &b| {
        scores[b as usize].partial_cmp(&scores[a as usize]).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Money/SALSA on the bipartite (CoT -> followed) graph; returns
/// (authority_scores, hub_scores) dense over the data graph's vertices.
pub fn money<G: GraphRep>(
    g: &G,
    cot: &[VertexId],
    iters: usize,
    enactor: &mut Enactor,
) -> (Vec<f64>, Vec<f64>) {
    let n = g.num_vertices();
    // in-CoT marker + hub scores init uniform
    let mut hub = vec![0.0f64; n];
    for &h in cot {
        hub[h as usize] = 1.0 / cot.len().max(1) as f64;
    }
    let mut auth = vec![0.0f64; n];
    // Authority in-degree *restricted to CoT hubs* for the SALSA backward
    // normalization.
    let mut auth_indeg = vec![0u32; n];
    for &h in cot {
        g.for_each_neighbor(h, |_, a| {
            auth_indeg[a as usize] += 1;
        });
    }

    for _ in 0..iters {
        if !enactor.budget_ok() {
            break;
        }
        // forward: hubs scatter to authorities (2-hop bipartite advance)
        let next_auth: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();
        let hub_frontier = Frontier::vertices(cot.to_vec());
        let strategy = enactor.strategy_for(g, cot.len());
        let ctx = enactor.ctx();
        let hub_ref = &hub;
        let fwd = |s: VertexId, d: VertexId, _e: usize| {
            let deg = g.degree(s);
            if deg > 0 {
                atomic_add_f64(&next_auth[d as usize], hub_ref[s as usize] / deg as f64);
            }
            false
        };
        advance::advance(&ctx, g, &hub_frontier, advance::AdvanceType::V2V, strategy, &fwd);
        for v in 0..n {
            auth[v] = f64::from_bits(next_auth[v].load(Ordering::Relaxed));
        }

        // backward: authorities push back to hubs (via hubs' own edges:
        // hub gathers auth/auth_indeg over its followings).
        let next_hub: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();
        let ctx = enactor.ctx();
        let auth_ref = &auth;
        let auth_indeg_ref = &auth_indeg;
        let bwd = |s: VertexId, d: VertexId, _e: usize| {
            let indeg = auth_indeg_ref[d as usize];
            if indeg > 0 {
                atomic_add_f64(&next_hub[s as usize], auth_ref[d as usize] / indeg as f64);
            }
            false
        };
        advance::advance(&ctx, g, &hub_frontier, advance::AdvanceType::V2V, strategy, &bwd);
        for &h in cot {
            hub[h as usize] = f64::from_bits(next_hub[h as usize].load(Ordering::Relaxed));
        }
    }
    (auth, hub)
}

/// Full WTF pipeline for `user`. K = CoT size (paper uses 1000),
/// `num_recs` recommendations returned. Generic over the graph
/// representation (all three stages are advances / streaming scans).
pub fn wtf<G: GraphRep>(
    g: &G,
    user: VertexId,
    k: usize,
    num_recs: usize,
    config: &Config,
) -> (WtfResult, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::WTF, 1);
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let t = Timer::start();
    let scores = ppr(g, user, 10, 0.85, &mut enactor);
    let ppr_ms = t.elapsed_ms();

    let t = Timer::start();
    let cot = circle_of_trust(&scores, user, k);
    let cot_ms = t.elapsed_ms();

    let t = Timer::start();
    let (auth, _hub) = money(g, &cot, 8, &mut enactor);
    let money_ms = t.elapsed_ms();

    // Recommend top authorities the user does not already follow.
    let mut follows: std::collections::HashSet<VertexId> = std::collections::HashSet::new();
    g.for_each_neighbor(user, |_, u| {
        follows.insert(u);
    });
    let mut recs: Vec<VertexId> = (0..g.num_vertices() as VertexId)
        .filter(|&v| v != user && !follows.contains(&v) && auth[v as usize] > 0.0)
        .collect();
    recs.sort_unstable_by(|&a, &b| {
        auth[b as usize].partial_cmp(&auth[a as usize]).unwrap().then(a.cmp(&b))
    });
    recs.truncate(num_recs);

    enactor.record_iteration(g.num_vertices(), recs.len(), ppr_ms + cot_ms + money_ms, false);
    let result = enactor.finish_run();
    (
        WtfResult {
            circle_of_trust: cot,
            recommendations: recs,
            ppr_scores: scores,
            ppr_ms,
            cot_ms,
            money_ms,
        },
        result,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder;
    use crate::graph::generators::{bipartite_follow_graph, bipartite::FollowGraphParams};

    #[test]
    fn ppr_concentrates_near_user() {
        // 0 follows 1, 1 follows 2, 3 isolated-ish
        let g = builder::from_edges(4, &[(0, 1), (1, 2), (3, 0)]);
        let mut e = Enactor::new(Config::default());
        let s = ppr(&g, 0, 20, 0.85, &mut e);
        assert!(s[0] > s[2], "restart mass at user");
        assert!(s[1] > s[2], "1-hop beats 2-hop");
        assert!(s[3] < 1e-12, "nothing flows to non-reachable 3");
    }

    #[test]
    fn batched_ppr_matches_per_user_within_tolerance() {
        let g = bipartite_follow_graph(&FollowGraphParams {
            users: 256,
            avg_follows: 6,
            ..Default::default()
        });
        let users: Vec<u32> = (0..16u32).map(|i| i * 3).collect();
        let (cols, run) = ppr_batch(&g, &users, 10, 0.85, &Config::default());
        assert_eq!(run.lanes, 16);
        for (lane, &u) in users.iter().enumerate() {
            let mut e = Enactor::new(Config::default());
            let want = ppr(&g, u, 10, 0.85, &mut e);
            for v in 0..g.num_vertices {
                let (a, b) = (cols[lane][v], want[v]);
                assert!(
                    (a - b).abs() <= 1e-9 * b.abs().max(1.0),
                    "lane {lane} user {u} v {v}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn cot_excludes_user_and_ranks() {
        let scores = vec![0.5, 0.1, 0.3, 0.0];
        let cot = circle_of_trust(&scores, 0, 2);
        assert_eq!(cot, vec![2, 1]);
    }

    #[test]
    fn wtf_recommends_friends_of_friends() {
        // user 0 follows 1,2; 1 and 2 both follow 3 => recommend 3.
        let g = builder::from_edges(5, &[(0, 1), (0, 2), (1, 3), (2, 3), (2, 4)]);
        let (r, _) = wtf(&g, 0, 3, 2, &Config::default());
        assert_eq!(r.recommendations.first(), Some(&3));
    }

    #[test]
    fn wtf_runs_on_generated_follow_graph() {
        let g = bipartite_follow_graph(&FollowGraphParams { users: 512, avg_follows: 8, ..Default::default() });
        let (r, run) = wtf(&g, 5, 50, 10, &Config::default());
        assert_eq!(r.circle_of_trust.len(), 50);
        assert!(r.recommendations.len() <= 10);
        assert!(run.edges_visited > 0);
    }
}
