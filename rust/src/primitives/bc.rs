//! Betweenness centrality (paper §6.3), Brandes's two-phase formulation:
//! a forward BFS advance accumulating shortest-path counts (sigma), then a
//! backward advance over the BFS levels accumulating dependency scores
//! (delta). Both phases are Gunrock advances on vertex frontiers with
//! different fused computations.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::operators::advance;
use crate::util::bitset::AtomicBitset;
use crate::util::timer::Timer;

pub struct BcProblem {
    /// Centrality scores from this source (un-normalized, directed sense).
    pub bc_values: Vec<f64>,
    pub sigma: Vec<u64>,
    pub depth: Vec<u32>,
}

#[inline]
fn atomic_add_f64(slot: &AtomicU64, add: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + add;
        match slot.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Single-source BC contribution (run over many sources and sum for full
/// BC; the benches use a sampled set of sources like McLaughlin-Bader).
/// Generic over the graph representation (both phases are plain advances).
pub fn bc_from_source<G: GraphRep>(
    g: &G,
    src: VertexId,
    config: &Config,
) -> (BcProblem, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::BC, 1);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let depth: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    let sigma: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    depth[src as usize].store(0, Ordering::Relaxed);
    sigma[src as usize].store(1, Ordering::Relaxed);

    let visited = AtomicBitset::new(n);
    visited.set(src as usize);

    // ---- Forward phase: BFS levels, accumulating sigma.
    let mut levels: Vec<Vec<VertexId>> = vec![vec![src]];
    let mut frontier = Frontier::single(src);
    let mut d: u32 = 0;
    while !frontier.is_empty() && enactor.proceed() {
        let t = Timer::start();
        let input_len = frontier.len();
        d += 1;
        let strategy = enactor.strategy_for(g, input_len);
        let ctx = enactor.ctx();
        let counters = &enactor.counters;
        let dd = d;
        let fun = |s: VertexId, dst: VertexId, _e: usize| {
            // claim or match depth, then accumulate sigma along BFS dag edges
            let cur = depth[dst as usize].load(Ordering::Relaxed);
            if cur == u32::MAX {
                counters.add_atomics(1);
                if depth[dst as usize]
                    .compare_exchange(u32::MAX, dd, Ordering::Relaxed, Ordering::Relaxed)
                    .is_err()
                {
                    // lost the race; depth now == dd (same level)
                }
            }
            if depth[dst as usize].load(Ordering::Relaxed) == dd {
                let s_sigma = sigma[s as usize].load(Ordering::Relaxed);
                counters.add_atomics(1);
                sigma[dst as usize].fetch_add(s_sigma, Ordering::Relaxed);
                // emit dst once (visited claim)
                visited.set(dst as usize)
            } else {
                false
            }
        };
        let next = advance::advance(&ctx, g, &frontier, advance::AdvanceType::V2V, strategy, &fun);
        enactor.record_iteration(input_len, next.len(), t.elapsed_ms(), false);
        if !next.is_empty() {
            levels.push(next.ids().to_vec());
        }
        frontier = next;
    }

    // ---- Backward phase: dependency accumulation over levels in reverse.
    let delta: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();
    for level in levels.iter().rev().take(levels.len().saturating_sub(1)) {
        if !enactor.budget_ok() {
            break;
        }
        let t = Timer::start();
        let lvl_frontier = Frontier::vertices(level.clone());
        let strategy = enactor.strategy_for(g, lvl_frontier.len());
        let ctx = enactor.ctx();
        // For w in level, for each neighbor v with depth[v] = depth[w]-1:
        // delta[v] += sigma[v]/sigma[w] * (1 + delta[w]).
        // We advance FROM the level and push contributions to predecessors
        // (edges are symmetric in the undirected benchmark graphs).
        let fun = |w: VertexId, v: VertexId, _e: usize| {
            let dw = depth[w as usize].load(Ordering::Relaxed);
            let dv = depth[v as usize].load(Ordering::Relaxed);
            if dv != u32::MAX && dw != u32::MAX && dv + 1 == dw {
                let sw = sigma[w as usize].load(Ordering::Relaxed);
                let sv = sigma[v as usize].load(Ordering::Relaxed);
                if sw > 0 {
                    let dep = f64::from_bits(delta[w as usize].load(Ordering::Relaxed));
                    atomic_add_f64(&delta[v as usize], sv as f64 / sw as f64 * (1.0 + dep));
                }
            }
            false
        };
        advance::advance(&ctx, g, &lvl_frontier, advance::AdvanceType::V2V, strategy, &fun);
        enactor.record_iteration(level.len(), 0, t.elapsed_ms(), false);
    }

    let bc_values: Vec<f64> = delta
        .iter()
        .enumerate()
        .map(|(v, a)| if v == src as usize { 0.0 } else { f64::from_bits(a.load(Ordering::Relaxed)) })
        .collect();
    let result = enactor.finish_run();
    (
        BcProblem {
            bc_values,
            sigma: sigma.into_iter().map(|a| a.into_inner()).collect(),
            depth: depth.into_iter().map(|a| a.into_inner()).collect(),
        },
        result,
    )
}

/// Multi-source (sampled) BC: sums per-source dependencies. `sources =
/// None` runs all vertices (exact BC, small graphs only).
pub fn bc<G: GraphRep>(
    g: &G,
    sources: Option<&[VertexId]>,
    config: &Config,
) -> (Vec<f64>, RunResult) {
    let n = g.num_vertices();
    let all: Vec<VertexId>;
    let srcs = match sources {
        Some(s) => s,
        None => {
            all = (0..n as VertexId).collect();
            &all
        }
    };
    let mut total = vec![0.0f64; n];
    let mut agg = RunResult::default();
    for &s in srcs {
        let (p, r) = bc_from_source(g, s, config);
        for (v, x) in p.bc_values.iter().enumerate() {
            total[v] += x;
        }
        agg.runtime_ms += r.runtime_ms;
        agg.edges_visited += r.edges_visited;
        agg.kernel_launches += r.kernel_launches;
        agg.atomics += r.atomics;
        agg.warp_efficiency = r.warp_efficiency; // last run's figure
        agg.iterations.extend(r.iterations);
        if let Some(interrupt) = r.interrupted {
            // budget tripped mid-source: stop sampling, report the trip
            agg.interrupted = Some(interrupt);
            break;
        }
    }
    (total, agg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::bc_brandes::bc_brandes;
    use crate::graph::builder;
    use crate::graph::generators::{rmat, rmat::RmatParams};

    #[test]
    fn path_center_has_highest_bc() {
        // path 0-1-2-3-4: vertex 2 lies on most shortest paths
        let g = builder::undirected_from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let (total, _) = bc(&g, None, &Config::default());
        assert!(total[2] > total[1]);
        assert!(total[1] > total[0]);
        assert!((total[0] - 0.0).abs() < 1e-12);
    }

    #[test]
    fn sigma_counts_shortest_paths() {
        // diamond 0->{1,2}->3 (undirected): two shortest paths 0..3
        let g = builder::undirected_from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let (p, _) = bc_from_source(&g, 0, &Config::default());
        assert_eq!(p.sigma[3], 2);
        assert_eq!(p.sigma[1], 1);
        assert_eq!(p.depth[3], 2);
    }

    #[test]
    fn matches_brandes_exact() {
        let g = rmat(&RmatParams { scale: 8, edge_factor: 4, ..Default::default() });
        let (got, _) = bc(&g, None, &Config::default());
        let want = bc_brandes(&g);
        for v in 0..g.num_vertices {
            assert!(
                (got[v] - want[v]).abs() < 1e-6 * (1.0 + want[v].abs()),
                "v={v}: {} vs {}",
                got[v],
                want[v]
            );
        }
    }
}
