//! Triangle counting (paper §6.6): the *forward/set-intersection*
//! formulation — form the degree-ordered edge list (each undirected edge
//! kept once, pointing from the higher-degree endpoint to the lower-degree
//! one), then run segmented intersection over the edge pairs. Implemented
//! with advance + filter + segmented-intersection, exactly the paper's
//! operator flow (Fig 14).
//!
//! Two variants reproduce Fig 25's series:
//! - `tc_intersect_full`: intersect the full adjacency lists;
//! - `tc_intersect_filtered`: first *reform the induced subgraph* with
//!   only the filtered (forward) edges, "effectively reducing five-sixths
//!   of the workload", then intersect.

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::graph::{builder, Coo, GraphRep, VertexId};
use crate::operators::segmented_intersection;
use crate::util::budget::BudgetProbe;
use crate::util::timer::Timer;

pub struct TcResult {
    pub triangles: u64,
    /// Per-edge triangle counts over the filtered (forward) edge list.
    pub per_edge: Vec<u32>,
}

/// Degree-ordered forward test: keep edge (u, v) if deg(u) > deg(v), ties
/// by id (paper: "only keep one edge that points from the node with larger
/// degree to the node with smaller degree").
#[inline]
fn forward_edge<G: GraphRep>(g: &G, u: VertexId, v: VertexId) -> bool {
    let (du, dv) = (g.degree(u), g.degree(v));
    du > dv || (du == dv && u > v)
}

/// Collect the filtered forward edge pairs with an expansion that emits
/// (src, dst) directly — avoiding the per-edge `edge_src` binary search a
/// V2E frontier would need on readback (§Perf iteration 4).
/// TC is iteration-free, so the deadline is polled inside the expansion
/// itself (amortized [`BudgetProbe`] shared by the workers). A trip means
/// the pair list is partial: callers must check `probe.tripped()` and
/// abandon the result rather than intersect a truncated list.
fn forward_pairs<G: GraphRep>(
    enactor: &Enactor,
    g: &G,
    probe: &BudgetProbe,
) -> Vec<(VertexId, VertexId)> {
    let n = g.num_vertices();
    let all: Vec<VertexId> = (0..n as VertexId).collect();
    let strategy = enactor.strategy_for(g, n);
    let flat = crate::load_balance::expand(
        strategy,
        g,
        &all,
        enactor.workers,
        &enactor.counters,
        |_i, s, _e, d, out: &mut Vec<VertexId>| {
            if probe.poll() && forward_edge(g, s, d) {
                out.push(s);
                out.push(d);
            }
        },
    );
    flat.chunks_exact(2).map(|p| (p[0], p[1])).collect()
}

/// TC over the full adjacency lists ("tc-intersection-full").
pub fn tc_intersect_full<G: GraphRep>(g: &G, config: &Config) -> (TcResult, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::TC, 1);
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();
    let t = Timer::start();
    let probe = BudgetProbe::new(&config.budget);
    let pairs = forward_pairs(&enactor, g, &probe);
    if let Some(interrupt) = probe.tripped() {
        enactor.note_interrupt(interrupt);
        enactor.record_iteration(pairs.len(), 0, t.elapsed_ms(), false);
        return (TcResult { triangles: 0, per_edge: Vec::new() }, enactor.finish_run());
    }
    let ctx = enactor.ctx();
    let r = segmented_intersection::segmented_intersect(&ctx, g, &pairs, false);
    enactor.record_iteration(pairs.len(), 0, t.elapsed_ms(), false);
    let result = enactor.finish_run();
    // Each triangle {a,b,c} is counted once per forward edge incident to
    // its two higher-degree endpoints — with full lists every triangle is
    // seen 3 times (once per edge of the triangle).
    (TcResult { triangles: r.total / 3, per_edge: r.counts }, result)
}

/// TC over the induced forward subgraph ("tc-intersection-filtered"):
/// rebuild a graph with only forward edges, so each triangle is counted
/// exactly once and intersections scan ~half-length lists. The induced
/// subgraph is a fresh run-time CSR whatever the input representation —
/// it is the algorithm's working set, not a decompression of the input.
pub fn tc_intersect_filtered<G: GraphRep>(g: &G, config: &Config) -> (TcResult, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::TC, 1);
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();
    let t0 = Timer::start();
    let probe = BudgetProbe::new(&config.budget);
    let pairs = forward_pairs(&enactor, g, &probe);
    if let Some(interrupt) = probe.tripped() {
        enactor.note_interrupt(interrupt);
        enactor.record_iteration(pairs.len(), 0, t0.elapsed_ms(), false);
        return (TcResult { triangles: 0, per_edge: Vec::new() }, enactor.finish_run());
    }

    // Reform the induced subgraph (paper: "reforming the induced subgraph
    // with only the edges not filtered").
    let mut coo = Coo::with_capacity(g.num_vertices(), pairs.len(), false);
    for &(u, v) in &pairs {
        coo.push(u, v);
    }
    let fwd = builder::from_coo(&coo, false);
    let ctx = enactor.ctx();
    let r = segmented_intersection::segmented_intersect(&ctx, &fwd, &pairs, false);
    enactor.record_iteration(pairs.len(), 0, t0.elapsed_ms(), false);
    let result = enactor.finish_run();
    (TcResult { triangles: r.total, per_edge: r.counts }, result)
}

/// Clustering coefficient per vertex from the segmented counts (the other
/// use the paper names for segmented intersection).
pub fn clustering_coefficient<G: GraphRep>(g: &G, config: &Config) -> Vec<f64> {
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();
    let probe = BudgetProbe::new(&config.budget);
    let pairs = forward_pairs(&enactor, g, &probe);
    let ctx = enactor.ctx();
    let r = segmented_intersection::segmented_intersect(&ctx, g, &pairs, false);
    // triangles per vertex: every intersection w of pair (u, v) closes a
    // triangle at u, v, and w.
    let mut tri = vec![0u64; g.num_vertices()];
    for (i, &(u, v)) in pairs.iter().enumerate() {
        let c = r.counts[i] as u64;
        tri[u as usize] += c;
        tri[v as usize] += c;
    }
    // (w side counted via the other two edges' intersections; with full
    // lists each triangle contributes twice per vertex.)
    (0..g.num_vertices())
        .map(|v| {
            let d = g.degree(v as VertexId);
            if d < 2 {
                0.0
            } else {
                tri[v] as f64 / (d * (d - 1)) as f64
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tc_forward::tc_forward;
    use crate::graph::builder;
    use crate::graph::generators::{smallworld::smallworld, smallworld::SmallWorldParams};

    #[test]
    fn k4_has_four_triangles() {
        let g = builder::undirected_from_edges(
            4,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],
        );
        let (full, _) = tc_intersect_full(&g, &Config::default());
        let (filt, _) = tc_intersect_filtered(&g, &Config::default());
        assert_eq!(full.triangles, 4);
        assert_eq!(filt.triangles, 4);
    }

    #[test]
    fn triangle_free_graph() {
        // bipartite = triangle-free
        let g = builder::undirected_from_edges(6, &[(0, 3), (0, 4), (1, 3), (1, 5), (2, 4)]);
        let (r, _) = tc_intersect_filtered(&g, &Config::default());
        assert_eq!(r.triangles, 0);
    }

    #[test]
    fn matches_baseline_on_smallworld() {
        let g = smallworld(&SmallWorldParams { n: 512, k: 8, beta: 0.1, ..Default::default() });
        let want = tc_forward(&g);
        let (full, _) = tc_intersect_full(&g, &Config::default());
        let (filt, _) = tc_intersect_filtered(&g, &Config::default());
        assert_eq!(full.triangles, want);
        assert_eq!(filt.triangles, want);
    }

    #[test]
    fn clustering_coefficient_triangle() {
        let g = builder::undirected_from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        let cc = clustering_coefficient(&g, &Config::default());
        for v in 0..3 {
            assert!(cc[v] > 0.0, "v={v}");
        }
    }
}
