//! The paper's graph primitives (§6), each assembled from the operator
//! set: BFS, SSSP, BC, PageRank, CC, TC, the WTF (Who-To-Follow)
//! pipeline, and subgraph matching.
//!
//! All of them are invoked through one surface: the [`api`] module's
//! [`api::Primitive`] trait and [`api::run_request`]/[`api::run_batch`]
//! dispatchers (CLI `run`, CLI `serve`, and programmatic callers alike).

pub mod api;
pub mod bc;
pub mod bfs;
pub mod cc;
pub mod color;
pub mod label_propagation;
pub mod mst;
pub mod pagerank;
pub mod sm;
pub mod sssp;
pub mod traversal_extras;
pub mod tc;
pub mod wtf;
