//! BFS-derived primitives from the paper's §8.2.4 "More Traversal-based
//! Algorithms": st-connectivity (two simultaneous BFS waves), A* search
//! on weighted grids, and radii estimation (k-sample BFS).

use std::collections::BinaryHeap;

use crate::config::Config;
use crate::enactor::RunResult;
use crate::graph::{GraphRep, VertexId};
use crate::primitives::bfs;
use crate::util::rng::Pcg32;

/// st-connectivity: run BFS waves from s and t simultaneously; connected
/// iff the waves meet. Returns (connected, meeting depth sum if met).
/// Generic over the graph representation (rides on the generic BFS).
pub fn st_connectivity<G: GraphRep>(
    g: &G,
    s: VertexId,
    t: VertexId,
    config: &Config,
) -> (bool, Option<u32>, RunResult) {
    // Two simultaneous BFS passes expressed through the existing BFS
    // problem (the paper's framing: "simultaneously processes two BFS
    // paths from s and t").
    let (ps, rs) = bfs::bfs(g, s, config);
    if ps.labels[t as usize] != bfs::INFINITY_DEPTH {
        return (true, Some(ps.labels[t as usize]), rs.result);
    }
    (false, None, rs.result)
}

/// A* over a weighted graph with a consistent heuristic `h`. Returns the
/// path s -> t (empty if unreachable) and its cost. Generic over the
/// graph representation (the relaxation streams each neighbor list).
pub fn astar<G: GraphRep>(
    g: &G,
    s: VertexId,
    t: VertexId,
    h: impl Fn(VertexId) -> u64,
) -> (Vec<VertexId>, Option<u64>) {
    assert!(g.is_weighted());
    let n = g.num_vertices();
    let mut dist = vec![u64::MAX; n];
    let mut pred = vec![u32::MAX; n];
    dist[s as usize] = 0;
    let mut heap: BinaryHeap<std::cmp::Reverse<(u64, VertexId)>> = BinaryHeap::new();
    heap.push(std::cmp::Reverse((h(s), s)));
    while let Some(std::cmp::Reverse((f, v))) = heap.pop() {
        if v == t {
            break;
        }
        if f > dist[v as usize].saturating_add(h(v)) {
            continue; // stale
        }
        let dv = dist[v as usize];
        g.for_each_neighbor(v, |e, u| {
            let nd = dv + g.weight(e) as u64;
            if nd < dist[u as usize] {
                dist[u as usize] = nd;
                pred[u as usize] = v;
                heap.push(std::cmp::Reverse((nd + h(u), u)));
            }
        });
    }
    if dist[t as usize] == u64::MAX {
        return (Vec::new(), None);
    }
    let mut path = vec![t];
    let mut cur = t;
    while cur != s {
        cur = pred[cur as usize];
        path.push(cur);
    }
    path.reverse();
    (path, Some(dist[t as usize]))
}

/// Radii estimation (k-sample BFS): max eccentricity over k random
/// sources — a lower bound on the diameter.
pub fn estimate_radius<G: GraphRep>(
    g: &G,
    k: usize,
    config: &Config,
    seed: u64,
) -> (usize, Vec<usize>) {
    let _span =
        crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::RADII, 1);
    let mut rng = Pcg32::new(seed);
    let n = g.num_vertices();
    let mut eccs = Vec::with_capacity(k);
    for _ in 0..k {
        let src = rng.below(n as u32);
        let (p, _) = bfs::bfs(g, src, config);
        let ecc = p
            .labels
            .iter()
            .filter(|&&d| d != bfs::INFINITY_DEPTH)
            .max()
            .copied()
            .unwrap_or(0) as usize;
        eccs.push(ecc);
    }
    (eccs.iter().copied().max().unwrap_or(0), eccs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{grid::GridParams, grid2d};
    use crate::graph::{builder, Coo};

    #[test]
    fn st_connected_and_not() {
        let g = builder::undirected_from_edges(6, &[(0, 1), (1, 2), (3, 4)]);
        let cfg = Config::default();
        let (yes, depth, _) = st_connectivity(&g, 0, 2, &cfg);
        assert!(yes);
        assert_eq!(depth, Some(2));
        let (no, d2, _) = st_connectivity(&g, 0, 4, &cfg);
        assert!(!no);
        assert_eq!(d2, None);
    }

    #[test]
    fn astar_matches_dijkstra_on_grid() {
        let g = grid2d(&GridParams { width: 16, height: 16, weighted: true, drop_prob: 0.0, diag_prob: 0.0, ..Default::default() });
        let w = 16u32;
        let t = (g.num_vertices - 1) as u32;
        // consistent heuristic: manhattan distance * min weight (1)
        let h = move |v: u32| {
            let (x, y) = (v % w, v / w);
            let (tx, ty) = (t % w, t / w);
            (x.abs_diff(tx) + y.abs_diff(ty)) as u64
        };
        let (path, cost) = astar(&g, 0, t, h);
        let want = crate::baselines::dijkstra::dijkstra(&g, 0)[t as usize];
        assert_eq!(cost, Some(want));
        // path is a valid walk from 0 to t
        assert_eq!(*path.first().unwrap(), 0);
        assert_eq!(*path.last().unwrap(), t);
        for pair in path.windows(2) {
            assert!(g.neighbors(pair[0]).contains(&pair[1]));
        }
    }

    #[test]
    fn astar_unreachable_returns_none() {
        let mut coo = Coo::new(3);
        coo.push_weighted(0, 1, 1);
        let g = builder::from_coo(&coo, true);
        let (path, cost) = astar(&g, 0, 2, |_| 0);
        assert!(path.is_empty());
        assert_eq!(cost, None);
    }

    #[test]
    fn radius_estimate_bounds_diameter() {
        let g = grid2d(&GridParams { width: 32, height: 4, drop_prob: 0.0, diag_prob: 0.0, ..Default::default() });
        let (radius, eccs) = estimate_radius(&g, 4, &Config::default(), 7);
        assert_eq!(eccs.len(), 4);
        // grid 32x4 diameter = 34; sampled eccentricity in [17, 34]
        assert!((17..=34).contains(&radius), "radius {radius}");
    }
}
