//! Connected components (paper §6.4), after Soman et al.: alternating
//! **hooking** (an operation over the edge frontier trying to join the two
//! endpoints' components) and **pointer-jumping** (a pass over the vertex
//! frontier collapsing component trees to stars), repeated until no
//! active edge remains.
//!
//! Within one hooking round every write is oriented consistently (odd
//! rounds: higher root id hooks under lower; even rounds: the reverse —
//! Soman's alternation, which speeds convergence), so the parent links
//! cannot form cycles. Edges are only *dropped* from the frontier by the
//! settle pass after pointer-jumping has stabilized the labels — dropping
//! on transient mid-round ids could split components (lost-update races).
//!
//! Frontier representation: the edge frontier starts as the full dense
//! bitmap (`all_edges`, O(m/64) to build) and the vertex frontier for
//! pointer-jumping is one hoisted dense full bitmap — the hybrid engine
//! demotes the edge frontier to a queue once few edges stay active.
//! Representations without O(1) edge-endpoint access take the
//! **vertex-grouped hooking walk** ([`cc_walk`]): each round streams
//! `for_each_neighbor` over vertices that still own live edges (word-
//! probed in the edge bitmap), so no 2×m endpoint table is ever
//! materialized — one m-bit bitmap replaces 8·m bytes of scratch.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::{Frontier, FrontierKind};
use crate::graph::{GraphRep, VertexId};
use crate::operators::{compute, filter};
use crate::util::bitset::AtomicBitset;
use crate::util::par;
use crate::util::timer::Timer;

pub struct CcProblem {
    pub component: Vec<u32>,
    pub num_components: usize,
}

/// Soman orientation: pick (winner, loser) roots for one hook.
#[inline]
fn orient(odd: bool, cs: u32, cd: u32) -> (u32, u32) {
    if odd == (cs < cd) {
        (cs, cd)
    } else {
        (cd, cs)
    }
}

/// Pointer-jumping to stars: repeat `comp[v] = comp[comp[v]]` passes over
/// the (dense, hoisted) vertex frontier until stable.
fn pointer_jump(enactor: &Enactor, vertex_frontier: &Frontier, comp: &[AtomicU32]) {
    let jumping = AtomicBool::new(true);
    while jumping.swap(false, Ordering::Relaxed) {
        let ctx = enactor.ctx();
        compute::compute(&ctx, vertex_frontier, |v: VertexId| {
            let c = comp[v as usize].load(Ordering::Relaxed);
            let cc = comp[c as usize].load(Ordering::Relaxed);
            if c != cc {
                comp[v as usize].store(cc, Ordering::Relaxed);
                jumping.store(true, Ordering::Relaxed);
            }
        });
    }
}

fn finish(comp: &[AtomicU32]) -> CcProblem {
    let component: Vec<u32> = comp.iter().map(|a| a.load(Ordering::Relaxed)).collect();
    let mut roots: Vec<u32> = component.clone();
    roots.sort_unstable();
    roots.dedup();
    CcProblem { component, num_components: roots.len() }
}

/// Generic over the graph representation. Raw CSR answers edge-endpoint
/// lookups in O(1) and hooks straight off the hybrid edge frontier; a
/// compressed representation would pay a binary search plus a prefix
/// decode *per edge per round*, so it takes the vertex-grouped walk
/// instead (see module docs) — no endpoint table either way.
pub fn cc<G: GraphRep>(g: &G, config: &Config) -> (CcProblem, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::CC, 1);
    let n = g.num_vertices();
    let m = g.num_edges();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let comp: Vec<AtomicU32> = (0..n).map(|v| AtomicU32::new(v as u32)).collect();
    let vertex_frontier = Frontier::all_vertices(n);

    if !G::O1_EDGE_ACCESS {
        let problem = cc_walk(g, &mut enactor, &comp, &vertex_frontier);
        let result = enactor.finish_run();
        return (problem, result);
    }

    let mut edge_frontier = Frontier::all_edges(m);
    if !enactor.densify_plain(m, m) {
        edge_frontier.to_sparse();
    }
    let mut settled = Frontier::empty(FrontierKind::Edge);
    let mut odd = true;

    while !edge_frontier.is_empty() && enactor.proceed() {
        let t = Timer::start();
        let input_len = edge_frontier.len();

        // --- Hooking: one pass over the edge frontier. Writes go to the
        // *root* slot (comp values are roots after the previous jumping
        // phase), consistently oriented within the round.
        {
            let ctx = enactor.ctx();
            let counters = &enactor.counters;
            let hook = |e: VertexId| {
                let eid = e as usize;
                let (s, d) = (g.edge_src(eid), g.edge_dst(eid));
                let cs = comp[s as usize].load(Ordering::Relaxed);
                let cd = comp[d as usize].load(Ordering::Relaxed);
                counters.add_edges(1);
                if cs == cd {
                    return;
                }
                let (winner, loser) = orient(odd, cs, cd);
                counters.add_atomics(1);
                comp[loser as usize].store(winner, Ordering::Relaxed);
            };
            compute::compute(&ctx, &edge_frontier, hook);
        }
        odd = !odd;

        // --- Pointer-jumping: collapse parent chains to stars.
        pointer_jump(&enactor, &vertex_frontier, &comp);

        // --- Settle: drop edges whose endpoints now share a (stable,
        // post-jump) component id — representation-preserving filter into
        // the recycled buffer, demoted once occupancy drops.
        {
            let ctx = enactor.ctx();
            let keep = |e: VertexId| {
                let eid = e as usize;
                let cs = comp[g.edge_src(eid) as usize].load(Ordering::Relaxed);
                let cd = comp[g.edge_dst(eid) as usize].load(Ordering::Relaxed);
                cs != cd
            };
            filter::filter_into(&ctx, &edge_frontier, &keep, &mut settled);
            std::mem::swap(&mut edge_frontier, &mut settled);
        }
        if edge_frontier.is_dense() && !enactor.densify_plain(m, edge_frontier.len()) {
            edge_frontier.to_sparse();
        }

        enactor.record_iteration(input_len, edge_frontier.len(), t.elapsed_ms(), false);
    }

    let problem = finish(&comp);
    let result = enactor.finish_run();
    (problem, result)
}

/// CC without O(1) edge-endpoint access (compressed representations):
/// the edge frontier is a dense m-bit bitmap and every hooking/settle
/// pass walks it **vertex-grouped** — vertices partition the worker
/// range; a vertex whose edge-id range holds no live bit (one or two
/// word probes) skips its neighbor decode entirely, and live edges hook
/// with source vertex known from the walk, so endpoints never need
/// random access. Replaces the former 2×m endpoint-table materialization
/// (8·m bytes of scratch) with the m-bit bitmap the frontier already is.
fn cc_walk<G: GraphRep>(
    g: &G,
    enactor: &mut Enactor,
    comp: &[AtomicU32],
    vertex_frontier: &Frontier,
) -> CcProblem {
    let n = g.num_vertices();
    let m = g.num_edges();
    let active = AtomicBitset::new(m);
    active.set_all();
    let mut remaining = m;
    let mut odd = true;

    while remaining > 0 && enactor.proceed() {
        let t = Timer::start();
        let input_len = remaining;

        // --- Hooking (vertex-grouped walk over live edges).
        {
            let counters = &enactor.counters;
            let round_odd = odd;
            par::run_partitioned(n, enactor.workers, |_, vs, ve| {
                for v in vs..ve {
                    let v = v as VertexId;
                    let deg = g.degree(v);
                    if deg == 0 {
                        continue;
                    }
                    let estart = g.edge_start(v);
                    if !active.any_in_range(estart, estart + deg) {
                        continue;
                    }
                    g.for_each_neighbor(v, |eid, d| {
                        if !active.get(eid) {
                            return;
                        }
                        let cs = comp[v as usize].load(Ordering::Relaxed);
                        let cd = comp[d as usize].load(Ordering::Relaxed);
                        counters.add_edges(1);
                        if cs == cd {
                            return;
                        }
                        let (winner, loser) = orient(round_odd, cs, cd);
                        counters.add_atomics(1);
                        comp[loser as usize].store(winner, Ordering::Relaxed);
                    });
                }
            });
            enactor.counters.add_kernel_launch();
        }
        odd = !odd;

        // --- Pointer-jumping.
        pointer_jump(enactor, vertex_frontier, comp);

        // --- Settle: clear bits of edges whose endpoints now agree.
        // In-place bit clears are safe: each live edge is examined by
        // exactly one worker, and clearing never resurrects work.
        {
            let cleared: Vec<usize> = par::run_partitioned(n, enactor.workers, |_, vs, ve| {
                let mut dropped = 0usize;
                for v in vs..ve {
                    let v = v as VertexId;
                    let deg = g.degree(v);
                    if deg == 0 {
                        continue;
                    }
                    let estart = g.edge_start(v);
                    if !active.any_in_range(estart, estart + deg) {
                        continue;
                    }
                    g.for_each_neighbor(v, |eid, d| {
                        if !active.get(eid) {
                            return;
                        }
                        let cs = comp[v as usize].load(Ordering::Relaxed);
                        let cd = comp[d as usize].load(Ordering::Relaxed);
                        if cs == cd {
                            active.clear_bit(eid);
                            dropped += 1;
                        }
                    });
                }
                dropped
            });
            enactor.counters.add_kernel_launch();
            let dropped: usize = cleared.iter().sum();
            enactor.counters.add_culled(dropped as u64);
            remaining -= dropped;
        }

        enactor.record_iteration(input_len, remaining, t.elapsed_ms(), false);
    }

    finish(comp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::cc_unionfind::cc_unionfind;
    use crate::graph::builder;
    use crate::graph::generators::{rmat, rmat::RmatParams};

    #[test]
    fn two_components() {
        let g = builder::undirected_from_edges(6, &[(0, 1), (1, 2), (3, 4)]);
        let (p, _) = cc(&g, &Config::default());
        assert_eq!(p.num_components, 3); // {0,1,2} {3,4} {5}
        assert_eq!(p.component[0], p.component[1]);
        assert_eq!(p.component[1], p.component[2]);
        assert_eq!(p.component[3], p.component[4]);
        assert_ne!(p.component[0], p.component[3]);
        assert_ne!(p.component[5], p.component[0]);
    }

    #[test]
    fn matches_union_find() {
        let g = rmat(&RmatParams { scale: 10, edge_factor: 4, ..Default::default() });
        let (p, _) = cc(&g, &Config::default());
        let want = cc_unionfind(&g);
        assert_eq!(p.num_components, want.1);
        // same partition: neighbors always share labels
        for v in 0..g.num_vertices {
            for &u in g.neighbors(v as u32) {
                assert_eq!(p.component[v], p.component[u as usize]);
                assert_eq!(want.0[v], want.0[u as usize]);
            }
        }
    }

    #[test]
    fn fully_connected_single_component() {
        let g = builder::undirected_from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let (p, _) = cc(&g, &Config::default());
        assert_eq!(p.num_components, 1);
    }

    #[test]
    fn labels_are_roots() {
        // every label must itself be a fixed point (star property)
        let g = rmat(&RmatParams { scale: 8, edge_factor: 2, ..Default::default() });
        let (p, _) = cc(&g, &Config::default());
        for v in 0..g.num_vertices {
            let c = p.component[v] as usize;
            assert_eq!(p.component[c], p.component[v], "non-star at {v}");
        }
    }

    #[test]
    fn walk_path_matches_table_free_o1_path() {
        // The compressed representation (no O(1) edge access) takes the
        // vertex-grouped walk; partitions must agree with the CSR run.
        use crate::graph::{Codec, CompressedCsr};
        let g = rmat(&RmatParams { scale: 9, edge_factor: 4, ..Default::default() });
        let cg = CompressedCsr::from_csr(&g, Codec::Varint);
        let (want, _) = cc(&g, &Config::default());
        let (got, _) = cc(&cg, &Config::default());
        assert_eq!(want.num_components, got.num_components);
        for v in 0..g.num_vertices {
            for &u in g.neighbors(v as u32) {
                assert_eq!(got.component[v], got.component[u as usize], "{v}-{u}");
            }
        }
    }

    #[test]
    fn forced_modes_agree() {
        use crate::frontier::HybridMode;
        let g = rmat(&RmatParams { scale: 9, edge_factor: 4, ..Default::default() });
        let (auto, _) = cc(&g, &Config::default());
        for mode in [HybridMode::ForceSparse, HybridMode::ForceDense] {
            let mut cfg = Config::default();
            cfg.frontier_mode = mode;
            let (got, _) = cc(&g, &cfg);
            assert_eq!(auto.num_components, got.num_components, "{mode}");
            for v in 0..g.num_vertices {
                for &u in g.neighbors(v as u32) {
                    assert_eq!(got.component[v], got.component[u as usize]);
                }
            }
        }
    }
}
