//! Connected components (paper §6.4), after Soman et al.: alternating
//! **hooking** (an operation over the edge frontier trying to join the two
//! endpoints' components) and **pointer-jumping** (a filter over the
//! vertex frontier collapsing component trees to stars), repeated until no
//! component id changes.
//!
//! Within one hooking round every write is oriented consistently (odd
//! rounds: higher root id hooks under lower; even rounds: the reverse —
//! Soman's alternation, which speeds convergence), so the parent links
//! cannot form cycles. Edges are only *dropped* from the frontier by the
//! filter step after pointer-jumping has stabilized the labels — dropping
//! on transient mid-round ids could split components (lost-update races).

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::operators::{compute, filter};
use crate::util::par;
use crate::util::timer::Timer;

pub struct CcProblem {
    pub component: Vec<u32>,
    pub num_components: usize,
}

/// Generic over the graph representation. Hooking random-accesses edge
/// endpoints by id every round; raw CSR answers that in O(1) from its
/// arrays, while a compressed representation would pay a binary search
/// plus a prefix decode *per edge per round* — so for non-O(1)
/// representations the endpoints are materialized once up front with a
/// single streaming decode (working-set cost: two edge-sized arrays,
/// amortized over every hooking round).
pub fn cc<G: GraphRep>(g: &G, config: &Config) -> (CcProblem, RunResult) {
    let n = g.num_vertices();
    let m = g.num_edges();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let table: Option<(Vec<VertexId>, Vec<VertexId>)> = if G::O1_EDGE_ACCESS {
        None
    } else {
        // One streaming decode of the whole graph, on the worker pool:
        // vertex ranges partition the edge-id space into disjoint slots,
        // so per-worker writes need no synchronization (same pattern as
        // neighborhood_reduce's exclusive output slots).
        let mut srcs = vec![0 as VertexId; m];
        let mut dsts = vec![0 as VertexId; m];
        let src_slots = par::Slots::new(srcs.as_mut_slice());
        let dst_slots = par::Slots::new(dsts.as_mut_slice());
        let (src_slots, dst_slots) = (&src_slots, &dst_slots);
        par::run_partitioned(n, enactor.workers, |_, s, e| {
            for v in s..e {
                let v = v as VertexId;
                g.for_each_neighbor(v, |eid, d| {
                    // SAFETY: edge id ranges of vertices s..e are disjoint
                    // from every other worker's; each slot written once.
                    unsafe {
                        src_slots.set(eid, v);
                        dst_slots.set(eid, d);
                    }
                });
            }
        });
        Some((srcs, dsts))
    };
    let endpoints = |eid: usize| -> (VertexId, VertexId) {
        match &table {
            Some((srcs, dsts)) => (srcs[eid], dsts[eid]),
            None => (g.edge_src(eid), g.edge_dst(eid)),
        }
    };

    let comp: Vec<AtomicU32> = (0..n).map(|v| AtomicU32::new(v as u32)).collect();
    let mut edge_frontier = Frontier::all_edges(m);
    let mut odd = true;

    while !edge_frontier.is_empty() && enactor.within_iteration_cap() {
        let t = Timer::start();
        let input_len = edge_frontier.len();

        // --- Hooking: one pass over the edge frontier. Writes go to the
        // *root* slot (comp values are roots after the previous jumping
        // phase), consistently oriented within the round.
        {
            let ctx = enactor.ctx();
            let counters = &enactor.counters;
            let hook = |e: VertexId| {
                let eid = e as usize;
                let (s, d) = endpoints(eid);
                let cs = comp[s as usize].load(Ordering::Relaxed);
                let cd = comp[d as usize].load(Ordering::Relaxed);
                counters.add_edges(1);
                if cs == cd {
                    return;
                }
                let (winner, loser) =
                    if odd == (cs < cd) { (cs, cd) } else { (cd, cs) };
                counters.add_atomics(1);
                comp[loser as usize].store(winner, Ordering::Relaxed);
            };
            compute::compute(&ctx, &edge_frontier, hook);
        }
        odd = !odd;

        // --- Pointer-jumping: collapse parent chains to stars.
        let vertex_frontier = Frontier::all_vertices(n);
        let jumping = AtomicBool::new(true);
        while jumping.swap(false, Ordering::Relaxed) {
            let ctx = enactor.ctx();
            let jump = |v: VertexId| -> bool {
                let c = comp[v as usize].load(Ordering::Relaxed);
                let cc = comp[c as usize].load(Ordering::Relaxed);
                if c != cc {
                    comp[v as usize].store(cc, Ordering::Relaxed);
                    jumping.store(true, Ordering::Relaxed);
                    true
                } else {
                    false
                }
            };
            filter::filter(&ctx, &vertex_frontier, &jump);
        }

        // --- Filter: drop edges whose endpoints now share a (stable,
        // post-jump) component id.
        {
            let ctx = enactor.ctx();
            let keep = |e: VertexId| {
                let (s, d) = endpoints(e as usize);
                let cs = comp[s as usize].load(Ordering::Relaxed);
                let cd = comp[d as usize].load(Ordering::Relaxed);
                cs != cd
            };
            edge_frontier = filter::filter(&ctx, &edge_frontier, &keep);
        }

        enactor.record_iteration(input_len, edge_frontier.len(), t.elapsed_ms(), false);
    }

    let component: Vec<u32> = comp.into_iter().map(|a| a.into_inner()).collect();
    let mut roots: Vec<u32> = component.clone();
    roots.sort_unstable();
    roots.dedup();
    let result = enactor.finish_run();
    (CcProblem { component, num_components: roots.len() }, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::cc_unionfind::cc_unionfind;
    use crate::graph::builder;
    use crate::graph::generators::{rmat, rmat::RmatParams};

    #[test]
    fn two_components() {
        let g = builder::undirected_from_edges(6, &[(0, 1), (1, 2), (3, 4)]);
        let (p, _) = cc(&g, &Config::default());
        assert_eq!(p.num_components, 3); // {0,1,2} {3,4} {5}
        assert_eq!(p.component[0], p.component[1]);
        assert_eq!(p.component[1], p.component[2]);
        assert_eq!(p.component[3], p.component[4]);
        assert_ne!(p.component[0], p.component[3]);
        assert_ne!(p.component[5], p.component[0]);
    }

    #[test]
    fn matches_union_find() {
        let g = rmat(&RmatParams { scale: 10, edge_factor: 4, ..Default::default() });
        let (p, _) = cc(&g, &Config::default());
        let want = cc_unionfind(&g);
        assert_eq!(p.num_components, want.1);
        // same partition: neighbors always share labels
        for v in 0..g.num_vertices {
            for &u in g.neighbors(v as u32) {
                assert_eq!(p.component[v], p.component[u as usize]);
                assert_eq!(want.0[v], want.0[u as usize]);
            }
        }
    }

    #[test]
    fn fully_connected_single_component() {
        let g = builder::undirected_from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let (p, _) = cc(&g, &Config::default());
        assert_eq!(p.num_components, 1);
    }

    #[test]
    fn labels_are_roots() {
        // every label must itself be a fixed point (star property)
        let g = rmat(&RmatParams { scale: 8, edge_factor: 2, ..Default::default() });
        let (p, _) = cc(&g, &Config::default());
        for v in 0..g.num_vertices {
            let c = p.component[v] as usize;
            assert_eq!(p.component[c], p.component[v] , "non-star at {v}");
        }
    }
}
