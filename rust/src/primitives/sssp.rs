//! Single-source shortest path (paper §6.2, Algorithm 1): per iteration an
//! advance relaxes distances with atomicMin, a filter removes redundant
//! vertices, and the optional two-level near/far priority queue
//! (delta-stepping, §5.1.5) reorganizes the remaining workload.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::lanes::{for_each_lane, LaneBits, LANES};
use crate::frontier::priority_queue::NearFarQueue;
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::operators::{advance, filter};
use crate::util::timer::Timer;

pub const INFINITY_DIST: u64 = u64::MAX / 4;

pub struct SsspProblem {
    pub dist: Vec<u64>,
    pub preds: Vec<i64>,
    pub src: VertexId,
}

/// Atomic min over u64 distance slots.
#[inline]
fn atomic_min(slot: &AtomicU64, value: u64) -> u64 {
    let mut cur = slot.load(Ordering::Relaxed);
    while value < cur {
        match slot.compare_exchange_weak(cur, value, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return cur,
            Err(now) => cur = now,
        }
    }
    cur
}

/// Run SSSP from `src`. With `config.sssp_delta > 0` the near/far priority
/// queue is used (delta-stepping); delta = 0 degenerates to Bellman-Ford
/// style full-frontier relaxation.
///
/// Generic over the graph representation: the relax functor reads weights
/// by global edge id, which is identical across representations, so raw
/// CSR and compressed `.gsr` graphs produce identical distances.
pub fn sssp<G: GraphRep>(g: &G, src: VertexId, config: &Config) -> (SsspProblem, RunResult) {
    assert!(g.is_weighted(), "SSSP needs edge weights (paper: uniform [1,64])");
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::SSSP, 1);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let dist: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(INFINITY_DIST)).collect();
    let preds: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    dist[src as usize].store(0, Ordering::Relaxed);

    // Output-queue-id stamps for redundant-vertex removal (Algorithm 1's
    // Remove_Redundant): a vertex stays in the new frontier only if it was
    // stamped during *this* iteration, collapsing duplicates to one copy.
    let stamps: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let mut queue_id: u32 = 0;

    let use_pq = config.sssp_delta > 0;
    let mut pq = NearFarQueue::new(config.sssp_delta.max(1));

    // Zero-alloc pipeline state: enactor-owned ping-pong queues, one
    // reusable raw-advance buffer, and a dedup bitset cleared (not
    // reallocated) per iteration.
    let mut bufs = std::mem::take(&mut enactor.frontiers);
    bufs.reset_single(src);
    let mut raw = Frontier::default();
    let seen = crate::util::bitset::AtomicBitset::new(n);

    while !bufs.current().is_empty() && enactor.proceed() {
        let t = Timer::start();
        let prev_edges = enactor.counters.edges();
        let input_len = bufs.current().len();
        queue_id += 1;
        let qid = queue_id;

        let strategy = enactor.strategy_for(g, input_len);
        // Hybrid: outside the near/far queue (which needs a sparse id
        // list to split), a heavy iteration writes its output bitmap
        // directly — the relax stamps plus the bitmap's fetch_or discard
        // make the separate Remove_Redundant filter pass unnecessary.
        let dense_out = !use_pq && enactor.densify_output(g, input_len);
        let ctx = enactor.ctx();

        // Advance: relax distances (Update_Label + Set_Pred fused).
        let relax = |s: VertexId, d: VertexId, e: usize| {
            let new_dist = dist[s as usize].load(Ordering::Relaxed) + g.weight(e) as u64;
            let old = atomic_min(&dist[d as usize], new_dist);
            if new_dist < old {
                preds[d as usize].store(s, Ordering::Relaxed);
                // first stamper this iteration emits the vertex
                stamps[d as usize].swap(qid, Ordering::Relaxed) != qid
            } else {
                false
            }
        };
        if dense_out {
            // Fused advance+filter: the bitmap output *is* the redundant-
            // vertex removal (one bit per stamped vertex).
            let (input, out) = bufs.split_mut();
            advance::advance_bitmap_into(&ctx, g, input, strategy, &relax, out);
        } else {
            advance::advance_into(
                &ctx,
                g,
                bufs.current(),
                advance::AdvanceType::V2V,
                strategy,
                &relax,
                &mut raw,
            );

            // Filter: Remove_Redundant — keep one copy per stamped vertex.
            // (the stamp swap in the advance already collapses most dupes;
            // the exact pass cleans up the rest deterministically.)
            seen.clear_all();
            filter::filter_into(&ctx, &raw, &|v: VertexId| seen.set(v as usize), bufs.next_mut());
        }

        // Priority queue: split into near/far, defer far work.
        if use_pq {
            let near = pq.split(bufs.next().ids().iter().copied(), |v| {
                dist[v as usize].load(Ordering::Relaxed)
            });
            // Adopt the split's allocation (no copy); the replaced
            // buffer's allocation is dropped, matching the pre-pipeline
            // cost of the PQ path (the split itself must allocate).
            if near.is_empty() {
                let lvl = pq.next_level(
                    |v| dist[v as usize].load(Ordering::Relaxed),
                    |v| dist[v as usize].load(Ordering::Relaxed) < INFINITY_DIST,
                );
                bufs.next_mut().set_ids(lvl);
            } else {
                bufs.next_mut().set_ids(near);
            }
        }

        // one relaxation atomic per traversed edge (batched stat)
        let e_now = enactor.counters.edges();
        enactor.counters.add_atomics(e_now.saturating_sub(prev_edges));
        let out_len = bufs.next().len();
        // Ligra-style downswitch before the next expansion.
        if bufs.next().is_dense() && !enactor.densify_output(g, out_len) {
            bufs.next_mut().to_sparse();
        }
        enactor.record_iteration(input_len, out_len, t.elapsed_ms(), false);
        bufs.swap();
    }
    enactor.frontiers = bufs;

    let result = enactor.finish_run();
    let problem = SsspProblem {
        dist: dist.into_iter().map(|a| a.into_inner()).collect(),
        preds: preds
            .into_iter()
            .map(|a| {
                let v = a.into_inner();
                if v == u32::MAX {
                    -1
                } else {
                    v as i64
                }
            })
            .collect(),
        src,
    };
    (problem, result)
}

/// Multi-source SSSP problem state: lane-major distance columns (see
/// [`crate::primitives::bfs::MsBfsProblem`] for why batched mode omits
/// predecessors).
pub struct MsSsspProblem {
    pub sources: Vec<VertexId>,
    /// `dist[lane][v]` = shortest distance from `sources[lane]` to `v`
    /// ([`INFINITY_DIST`] if unreachable).
    pub dist: Vec<Vec<u64>>,
    /// Iteration at which each lane's frontier last emptied.
    pub settled_at: Vec<u32>,
}

/// Bit-parallel multi-source SSSP: lane-masked Bellman-Ford relaxation.
/// Each edge is decoded once per iteration for the whole batch; the relax
/// runs an `atomicMin` per *active lane*, and a lane re-enters the
/// frontier only where its distance improved. The near/far queue does not
/// apply here (64 instances would need 64 independent priority levels —
/// the batch's shared-decode win is the reordering win's replacement).
///
/// Per-lane distances are **bit-identical** to [`sssp`] from the same
/// source: integer shortest distances are the unique fixed point of
/// relaxation, reached exactly by both schedules.
pub fn multi_source_sssp<G: GraphRep>(
    g: &G,
    sources: &[VertexId],
    config: &Config,
) -> (MsSsspProblem, RunResult) {
    assert!(g.is_weighted(), "SSSP needs edge weights (paper: uniform [1,64])");
    let k = sources.len();
    assert!(
        (1..=LANES).contains(&k),
        "multi_source_sssp takes 1..={LANES} sources, got {k}"
    );
    let _span =
        crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::SSSP, k as u64);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let dist: Vec<Vec<AtomicU64>> =
        (0..k).map(|_| (0..n).map(|_| AtomicU64::new(INFINITY_DIST)).collect()).collect();
    let mut cur = LaneBits::new(n);
    let mut next = LaneBits::new(n);
    for (lane, &src) in sources.iter().enumerate() {
        cur.merge(src as usize, 1 << lane);
        dist[lane][src as usize].store(0, Ordering::Relaxed);
    }
    cur.seal();

    let mut settled_at = vec![0u32; k];
    let mut live: u64 = if k == LANES { u64::MAX } else { (1u64 << k) - 1 };
    let mut round: u32 = 0;
    while !cur.is_empty() && enactor.proceed() {
        let t = Timer::start();
        let prev_edges = enactor.counters.edges();
        let input_len = cur.active_vertices();
        round += 1;
        let strategy = enactor.strategy_for(g, input_len);
        let ctx = enactor.ctx();
        let dist = &dist;
        advance::advance_lanes_into(
            &ctx,
            g,
            &cur,
            strategy,
            &|s: VertexId, d: VertexId, e: usize, mask: u64| {
                let w = g.weight(e) as u64;
                let mut improved = 0u64;
                for_each_lane(mask, |lane| {
                    let nd = dist[lane][s as usize].load(Ordering::Relaxed) + w;
                    let old = atomic_min(&dist[lane][d as usize], nd);
                    if nd < old {
                        improved |= 1 << lane;
                    }
                });
                improved
            },
            &mut next,
        );
        let gone = live & !next.lane_union();
        if gone != 0 {
            for_each_lane(gone, |lane| settled_at[lane] = round);
            live &= next.lane_union();
        }
        // one relaxation atomic per traversed lane word (batched stat,
        // mirroring the single-source accounting)
        let e_now = enactor.counters.edges();
        enactor.counters.add_atomics(e_now.saturating_sub(prev_edges));
        enactor.record_iteration(input_len, next.active_vertices(), t.elapsed_ms(), false);
        std::mem::swap(&mut cur, &mut next);
    }

    let mut result = enactor.finish_run();
    result.lanes = k;
    let problem = MsSsspProblem {
        sources: sources.to_vec(),
        dist: dist
            .into_iter()
            .map(|col| col.into_iter().map(|a| a.into_inner()).collect())
            .collect(),
        settled_at,
    };
    (problem, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::dijkstra::dijkstra;
    use crate::graph::generators::{grid::GridParams, grid2d, rmat, rmat::RmatParams};
    use crate::graph::{builder, Coo, Csr};

    fn weighted_triangle() -> Csr {
        let mut coo = Coo::new(3);
        coo.push_weighted(0, 1, 10);
        coo.push_weighted(0, 2, 3);
        coo.push_weighted(2, 1, 3);
        builder::from_coo(&coo, true)
    }

    #[test]
    fn takes_cheaper_path() {
        let g = weighted_triangle();
        let (p, _) = sssp(&g, 0, &Config::default());
        assert_eq!(p.dist[1], 6); // via 2, not direct 10
        assert_eq!(p.dist[2], 3);
        assert_eq!(p.preds[1], 2);
    }

    #[test]
    fn matches_dijkstra_on_rmat() {
        let g = rmat(&RmatParams { scale: 10, edge_factor: 8, weighted: true, ..Default::default() });
        let (p, _) = sssp(&g, 0, &Config::default());
        let want = dijkstra(&g, 0);
        assert_eq!(p.dist, want);
    }

    #[test]
    fn matches_dijkstra_on_grid_with_and_without_pq() {
        let g = grid2d(&GridParams { width: 24, height: 24, weighted: true, ..Default::default() });
        let want = dijkstra(&g, 0);
        let (with_pq, _) = sssp(&g, 0, &Config::default());
        assert_eq!(with_pq.dist, want);
        let mut cfg = Config::default();
        cfg.sssp_delta = 0; // Bellman-Ford mode
        let (no_pq, _) = sssp(&g, 0, &cfg);
        assert_eq!(no_pq.dist, want);
    }

    #[test]
    fn multi_source_matches_sequential_bit_exact() {
        let g =
            rmat(&RmatParams { scale: 9, edge_factor: 8, weighted: true, ..Default::default() });
        let sources: Vec<u32> = (0..32u32).map(|i| (i * 13) % g.num_vertices as u32).collect();
        let cfg = Config::default();
        let (ms, r) = multi_source_sssp(&g, &sources, &cfg);
        assert_eq!(r.lanes, 32);
        for (lane, &src) in sources.iter().enumerate() {
            let (p, _) = sssp(&g, src, &cfg);
            assert_eq!(ms.dist[lane], p.dist, "lane {lane} src {src}");
        }
    }

    #[test]
    fn batched_takes_cheaper_path_per_lane() {
        let g = weighted_triangle();
        let (ms, _) = multi_source_sssp(&g, &[0, 2], &Config::default());
        assert_eq!(ms.dist[0], vec![0, 6, 3]);
        assert_eq!(ms.dist[1], vec![INFINITY_DIST, 3, 0]);
    }

    #[test]
    fn unreachable_is_infinity() {
        let mut coo = Coo::new(3);
        coo.push_weighted(0, 1, 1);
        let g = builder::from_coo(&coo, true);
        let (p, _) = sssp(&g, 0, &Config::default());
        assert_eq!(p.dist[2], INFINITY_DIST);
    }
}
