//! The unified primitive call surface: one [`Request`] in, one
//! [`Response`] out, for every primitive in the suite.
//!
//! Before this layer each primitive had an ad-hoc signature (`bfs`
//! returns `(BfsProblem, BfsStats)`, `sssp` returns `(SsspProblem,
//! RunResult)`, `wtf` its own shape…), so every caller — the CLI, the
//! query service, tests — needed a per-primitive arm. The [`Primitive`]
//! trait normalizes them: a typed [`PrimitiveKind`] selects the
//! algorithm, [`Params`] carries the knobs that are per-request rather
//! than per-[`Config`], and the result is always an [`Output`] plus one
//! [`RunResult`]. The CLI `run` arm, the CLI `serve` loop, and the
//! programmatic API all dispatch through [`run_request`]/[`run_batch`] —
//! there is no second way to invoke a primitive.
//!
//! Failures are values, not panics: [`QueryError`] covers malformed
//! requests (bad source, missing weights, missing in-edge view) so a
//! long-lived service degrades to an error response where the one-shot
//! CLI used to be allowed to die.

use crate::config::Config;
use crate::enactor::RunResult;
use crate::frontier::lanes::LANES;
use crate::obs;
use crate::graph::{GraphRep, VertexId};
use crate::harness::suite;
use crate::util::budget::{Interrupt, RunBudget};
use crate::primitives::{
    bc, bfs, cc, color, label_propagation, mst, pagerank, sssp, tc, traversal_extras, wtf,
};

/// Which primitive a request runs (the paper's §6 suite plus the WTF
/// sub-stage PPR, servable on its own).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrimitiveKind {
    Bfs,
    Sssp,
    Bc,
    PageRank,
    Cc,
    Tc,
    Wtf,
    Ppr,
    Mst,
    Color,
    Mis,
    Lp,
    Radii,
}

impl PrimitiveKind {
    /// Kinds that traverse from a query vertex (the rest are whole-graph).
    pub fn needs_source(self) -> bool {
        matches!(
            self,
            PrimitiveKind::Bfs
                | PrimitiveKind::Sssp
                | PrimitiveKind::Bc
                | PrimitiveKind::Wtf
                | PrimitiveKind::Ppr
        )
    }

    /// Kinds that require edge weights on the graph.
    pub fn needs_weights(self) -> bool {
        matches!(self, PrimitiveKind::Sssp | PrimitiveKind::Mst)
    }

    /// Kinds with a bit-parallel multi-source engine: a 64-source batch
    /// runs as one lane-word traversal instead of 64 sequential runs.
    pub fn batchable(self) -> bool {
        matches!(self, PrimitiveKind::Bfs | PrimitiveKind::Sssp | PrimitiveKind::Ppr)
    }

    /// Stable numeric tag for tracing and metrics — the index into
    /// [`crate::obs::tags::NAMES`], so `obs::prim_name(kind.tag())`
    /// renders the same string as `Display`.
    pub fn tag(self) -> u64 {
        match self {
            PrimitiveKind::Bfs => obs::tags::BFS,
            PrimitiveKind::Sssp => obs::tags::SSSP,
            PrimitiveKind::Bc => obs::tags::BC,
            PrimitiveKind::PageRank => obs::tags::PAGERANK,
            PrimitiveKind::Cc => obs::tags::CC,
            PrimitiveKind::Tc => obs::tags::TC,
            PrimitiveKind::Wtf => obs::tags::WTF,
            PrimitiveKind::Ppr => obs::tags::PPR,
            PrimitiveKind::Mst => obs::tags::MST,
            PrimitiveKind::Color => obs::tags::COLOR,
            PrimitiveKind::Mis => obs::tags::MIS,
            PrimitiveKind::Lp => obs::tags::LP,
            PrimitiveKind::Radii => obs::tags::RADII,
        }
    }
}

impl std::str::FromStr for PrimitiveKind {
    type Err = QueryError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "bfs" => Ok(PrimitiveKind::Bfs),
            "sssp" => Ok(PrimitiveKind::Sssp),
            "bc" => Ok(PrimitiveKind::Bc),
            "pagerank" | "pr" => Ok(PrimitiveKind::PageRank),
            "cc" => Ok(PrimitiveKind::Cc),
            "tc" => Ok(PrimitiveKind::Tc),
            "wtf" => Ok(PrimitiveKind::Wtf),
            "ppr" => Ok(PrimitiveKind::Ppr),
            "mst" => Ok(PrimitiveKind::Mst),
            "color" => Ok(PrimitiveKind::Color),
            "mis" => Ok(PrimitiveKind::Mis),
            "lp" | "label-propagation" => Ok(PrimitiveKind::Lp),
            "radii" => Ok(PrimitiveKind::Radii),
            other => Err(QueryError::UnknownPrimitive(other.to_string())),
        }
    }
}

impl std::fmt::Display for PrimitiveKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            PrimitiveKind::Bfs => "bfs",
            PrimitiveKind::Sssp => "sssp",
            PrimitiveKind::Bc => "bc",
            PrimitiveKind::PageRank => "pagerank",
            PrimitiveKind::Cc => "cc",
            PrimitiveKind::Tc => "tc",
            PrimitiveKind::Wtf => "wtf",
            PrimitiveKind::Ppr => "ppr",
            PrimitiveKind::Mst => "mst",
            PrimitiveKind::Color => "color",
            PrimitiveKind::Mis => "mis",
            PrimitiveKind::Lp => "lp",
            PrimitiveKind::Radii => "radii",
        };
        f.write_str(s)
    }
}

/// Per-request knobs (distinct from [`Config`], which configures the
/// engine). Defaults match the paper's settings and the CLI's historical
/// hardcoded values.
#[derive(Clone, Debug)]
pub struct Params {
    /// PageRank: pull-mode gather (requires an in-edge view).
    pub pull: bool,
    /// WTF: Circle-of-Trust size (original WTF uses 1000).
    pub cot_size: usize,
    /// WTF/PPR: recommendations returned.
    pub num_recs: usize,
    /// PPR: power iterations.
    pub ppr_iters: usize,
    /// PPR: damping factor.
    pub ppr_damping: f64,
    /// Radii: BFS samples for the pseudo-radius estimate.
    pub radii_samples: usize,
    /// Run budget for this request (deadline / cancellation token /
    /// iteration cap). Merged with the config's budget — the tighter of
    /// both wins — and checked at every BSP iteration boundary; a trip
    /// turns the whole call into [`QueryError::DeadlineExceeded`] /
    /// [`QueryError::Cancelled`] with partial-progress stats.
    pub budget: RunBudget,
}

impl Default for Params {
    fn default() -> Self {
        Params {
            pull: false,
            cot_size: 100,
            num_recs: 10,
            ppr_iters: 10,
            ppr_damping: 0.85,
            radii_samples: 8,
            budget: RunBudget::none(),
        }
    }
}

/// A primitive invocation: what to run, from where, with which knobs.
#[derive(Clone, Debug)]
pub struct Request {
    pub kind: PrimitiveKind,
    /// Query vertices. Empty + a source-needing kind resolves to the
    /// max-degree vertex (the suite's default); whole-graph kinds ignore
    /// it. More than one source batches through the lane engine.
    pub sources: Vec<VertexId>,
    pub params: Params,
}

impl Request {
    pub fn new(kind: PrimitiveKind) -> Self {
        Request { kind, sources: Vec::new(), params: Params::default() }
    }

    pub fn with_source(kind: PrimitiveKind, src: VertexId) -> Self {
        Request { kind, sources: vec![src], params: Params::default() }
    }
}

/// Typed per-primitive results. Dense fields (labels, distances, ranks)
/// are full vertex-indexed columns; point answers (one hop count, one
/// distance) are reads into them, which is what makes the columns
/// cacheable as landmarks in the query service.
#[derive(Clone, Debug)]
pub enum Output {
    /// Depth labels ([`bfs::INFINITY_DEPTH`] = unreachable). `preds` is
    /// empty in batched mode (see [`bfs::MsBfsProblem`]).
    Bfs { labels: Vec<u32>, preds: Vec<i64>, push_iterations: usize, pull_iterations: usize },
    /// Distances ([`sssp::INFINITY_DIST`] = unreachable). `preds` is
    /// empty in batched mode.
    Sssp { dist: Vec<u64>, preds: Vec<i64> },
    Bc { scores: Vec<f64> },
    PageRank { ranks: Vec<f64>, iterations: usize },
    Cc { component: Vec<u32>, num_components: usize },
    Tc { triangles: u64 },
    Wtf { recommendations: Vec<VertexId>, circle_of_trust: Vec<VertexId>, scores: Vec<f64> },
    Ppr { scores: Vec<f64>, recommendations: Vec<VertexId> },
    Mst { tree_edges: usize, total_weight: u64 },
    Color { num_colors: usize },
    Mis { size: usize },
    Lp { num_communities: usize, iterations: usize },
    Radii { radius: usize, eccentricities: Vec<usize> },
}

/// Compact per-run traversal profile derived from the engine's
/// per-iteration trail: how many BSP iterations ran, the widest frontier
/// seen, and the push/pull split. Carried on [`Response`] so service
/// clients see the traversal shape without the full per-iteration vector.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IterationSummary {
    /// BSP iterations completed.
    pub count: usize,
    /// Largest frontier (input or output side) across iterations.
    pub max_frontier: usize,
    /// Iterations run in push (scatter) mode.
    pub push: usize,
    /// Iterations run in pull (gather) mode.
    pub pull: usize,
    /// Edges touched across all iterations.
    pub edges: u64,
}

impl IterationSummary {
    /// Summarize a run's iteration trail; `None` when the engine recorded
    /// no iterations (non-iterative kinds such as TC or MST).
    pub fn from_run(run: &RunResult) -> Option<IterationSummary> {
        if run.iterations.is_empty() {
            return None;
        }
        let mut s = IterationSummary { count: run.iterations.len(), ..Default::default() };
        for it in &run.iterations {
            s.max_frontier = s.max_frontier.max(it.input_frontier).max(it.output_frontier);
            if it.pull {
                s.pull += 1;
            } else {
                s.push += 1;
            }
            s.edges += it.edges_this_iter;
        }
        Some(s)
    }
}

/// One primitive run's result: the typed output plus the engine stats.
#[derive(Clone, Debug)]
pub struct Response {
    pub kind: PrimitiveKind,
    /// The resolved query vertex (None for whole-graph kinds).
    pub source: Option<VertexId>,
    pub output: Output,
    /// Engine stats; in batched mode every lane's response shares the
    /// batch's run (`run.lanes` > 1 tells them apart).
    pub run: RunResult,
    /// Traversal-shape summary of `run.iterations`, filled centrally by
    /// [`run_request`]/[`run_batch`] (`None` when the engine recorded no
    /// iteration trail).
    pub iterations: Option<IterationSummary>,
}

/// Typed failures for graph-load and query paths: a malformed request is
/// an error response, never a panic — the query service stays up, the
/// CLI maps it to a nonzero exit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QueryError {
    UnknownPrimitive(String),
    UnknownDataset(String),
    InvalidSource { source: VertexId, num_vertices: usize },
    NeedsWeights { primitive: PrimitiveKind },
    NeedsInEdges { what: &'static str },
    /// Admission control: the service queue is at capacity.
    QueueFull { limit: usize },
    /// The service shut down before this request was answered.
    ServiceStopped,
    Malformed(String),
    /// The run budget's deadline expired mid-run; the counters report
    /// the partial progress made (wall clock spent, BSP iterations
    /// completed before the trip).
    DeadlineExceeded { elapsed_ms: u64, completed_iterations: usize },
    /// The request's cancellation token fired mid-run.
    Cancelled { completed_iterations: usize },
    /// The run budget's own iteration cap was reached (distinct from
    /// the engine's silent `max_iters` convergence guard).
    IterationLimit { completed_iterations: usize },
    /// The engine failed internally (a panic caught and contained by
    /// the service); the query was isolated, the service stays up.
    Internal(String),
    /// Load shedding: the query aged out of the queue before the
    /// batcher could run it. `level` is the degradation-ladder rung at
    /// the moment of shedding.
    Overloaded { queued_ms: u64, level: crate::util::resources::DegradationLevel },
    /// The resource governor refused the memory this query would need
    /// (budget headroom exhausted, admission closed at `Shed`, or an
    /// injected pressure fault). Carries the ladder rung at refusal.
    ResourceExhausted { level: crate::util::resources::DegradationLevel, needed_bytes: u64 },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::UnknownPrimitive(s) => write!(f, "unknown primitive {s}"),
            QueryError::UnknownDataset(s) => {
                write!(f, "unknown dataset {s} (see `gunrock datasets`)")
            }
            QueryError::InvalidSource { source, num_vertices } => {
                write!(f, "source vertex {source} out of range (graph has {num_vertices} vertices)")
            }
            QueryError::NeedsWeights { primitive } => {
                write!(f, "{primitive} needs edge weights (load with --weighted)")
            }
            QueryError::NeedsInEdges { what } => {
                write!(f, "{what} requires an in-edge view (re-convert with in-edges)")
            }
            QueryError::QueueFull { limit } => {
                write!(f, "service queue full (limit {limit}), request rejected")
            }
            QueryError::ServiceStopped => write!(f, "query service stopped"),
            QueryError::Malformed(s) => write!(f, "malformed request: {s}"),
            QueryError::DeadlineExceeded { elapsed_ms, completed_iterations } => write!(
                f,
                "deadline exceeded after {elapsed_ms} ms ({completed_iterations} iterations done)"
            ),
            QueryError::Cancelled { completed_iterations } => {
                write!(f, "cancelled ({completed_iterations} iterations done)")
            }
            QueryError::IterationLimit { completed_iterations } => {
                write!(f, "iteration budget exhausted after {completed_iterations} iterations")
            }
            QueryError::Internal(s) => write!(f, "internal error: {s}"),
            QueryError::Overloaded { queued_ms, level } => {
                write!(f, "service overloaded (ladder {level}): shed after {queued_ms} ms in queue")
            }
            QueryError::ResourceExhausted { level, needed_bytes } => write!(
                f,
                "resource exhausted (ladder {level}): {needed_bytes} bytes over the memory budget"
            ),
        }
    }
}

impl std::error::Error for QueryError {}

/// Validate a request against a graph and resolve its query vertex:
/// bounds-check every source, default an absent one to the max-degree
/// vertex, and check the graph provides what the primitive needs.
fn validate<G: GraphRep>(g: &G, req: &Request) -> Result<Option<VertexId>, QueryError> {
    let n = g.num_vertices();
    if req.kind.needs_weights() && !g.is_weighted() {
        return Err(QueryError::NeedsWeights { primitive: req.kind });
    }
    if req.kind == PrimitiveKind::PageRank && req.params.pull && !g.has_in_edges() {
        return Err(QueryError::NeedsInEdges { what: "pull PageRank" });
    }
    for &s in &req.sources {
        if s as usize >= n {
            return Err(QueryError::InvalidSource { source: s, num_vertices: n });
        }
    }
    if !req.kind.needs_source() {
        return Ok(None);
    }
    Ok(Some(match req.sources.first() {
        Some(&s) => s,
        None => {
            if n == 0 {
                return Err(QueryError::Malformed("empty graph".to_string()));
            }
            suite::pick_source(g)
        }
    }))
}

/// Bounds-check a batch's sources (batch entry points take sources
/// explicitly, so none is defaulted).
fn validate_batch<G: GraphRep>(
    g: &G,
    sources: &[VertexId],
    req: &Request,
) -> Result<(), QueryError> {
    if sources.is_empty() {
        return Err(QueryError::Malformed("batch of zero sources".to_string()));
    }
    if req.kind.needs_weights() && !g.is_weighted() {
        return Err(QueryError::NeedsWeights { primitive: req.kind });
    }
    let n = g.num_vertices();
    for &s in sources {
        if s as usize >= n {
            return Err(QueryError::InvalidSource { source: s, num_vertices: n });
        }
    }
    Ok(())
}

/// A primitive behind the unified surface. Implementations are marker
/// structs (e.g. [`Bfs`]); the graph stays a method-level generic so one
/// trait serves every [`GraphRep`]. `run_batch` defaults to sequential
/// per-source runs; the lane-batched kinds override it with the
/// bit-parallel engines.
pub trait Primitive {
    const KIND: PrimitiveKind;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError>;

    fn run_batch<G: GraphRep>(
        g: &G,
        sources: &[VertexId],
        req: &Request,
        cfg: &Config,
    ) -> Result<Vec<Response>, QueryError> {
        validate_batch(g, sources, req)?;
        sources
            .iter()
            .map(|&s| {
                let mut one = req.clone();
                one.sources = vec![s];
                Self::run(g, &one, cfg)
            })
            .collect()
    }
}

/// Marker types implementing [`Primitive`] — named after the kinds.
pub struct Bfs;
pub struct Sssp;
pub struct Bc;
pub struct PageRank;
pub struct Cc;
pub struct Tc;
pub struct Wtf;
pub struct Ppr;
pub struct Mst;
pub struct ColorPrim;
pub struct Mis;
pub struct Lp;
pub struct Radii;

impl Primitive for Bfs {
    const KIND: PrimitiveKind = PrimitiveKind::Bfs;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        let src = validate(g, req)?.expect("bfs needs a source");
        let (prob, st) = bfs::bfs(g, src, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: Some(src),
            output: Output::Bfs {
                labels: prob.labels,
                preds: prob.preds,
                push_iterations: st.push_iterations,
                pull_iterations: st.pull_iterations,
            },
            run: st.result,
            iterations: None,
        })
    }

    fn run_batch<G: GraphRep>(
        g: &G,
        sources: &[VertexId],
        req: &Request,
        cfg: &Config,
    ) -> Result<Vec<Response>, QueryError> {
        validate_batch(g, sources, req)?;
        let mut out = Vec::with_capacity(sources.len());
        for chunk in sources.chunks(LANES) {
            let (ms, run) = bfs::multi_source_bfs(g, chunk, cfg);
            let iters = run.num_iterations();
            for (lane, &src) in chunk.iter().enumerate() {
                out.push(Response {
                    kind: Self::KIND,
                    source: Some(src),
                    output: Output::Bfs {
                        labels: ms.labels[lane].clone(),
                        preds: Vec::new(),
                        push_iterations: iters,
                        pull_iterations: 0,
                    },
                    run: run.clone(),
                    iterations: None,
                });
            }
        }
        Ok(out)
    }
}

impl Primitive for Sssp {
    const KIND: PrimitiveKind = PrimitiveKind::Sssp;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        let src = validate(g, req)?.expect("sssp needs a source");
        let (prob, run) = sssp::sssp(g, src, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: Some(src),
            output: Output::Sssp { dist: prob.dist, preds: prob.preds },
            run,
            iterations: None,
        })
    }

    fn run_batch<G: GraphRep>(
        g: &G,
        sources: &[VertexId],
        req: &Request,
        cfg: &Config,
    ) -> Result<Vec<Response>, QueryError> {
        validate_batch(g, sources, req)?;
        let mut out = Vec::with_capacity(sources.len());
        for chunk in sources.chunks(LANES) {
            let (ms, run) = sssp::multi_source_sssp(g, chunk, cfg);
            for (lane, &src) in chunk.iter().enumerate() {
                out.push(Response {
                    kind: Self::KIND,
                    source: Some(src),
                    output: Output::Sssp { dist: ms.dist[lane].clone(), preds: Vec::new() },
                    run: run.clone(),
                    iterations: None,
                });
            }
        }
        Ok(out)
    }
}

impl Primitive for Bc {
    const KIND: PrimitiveKind = PrimitiveKind::Bc;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        let src = validate(g, req)?.expect("bc needs a source");
        let (prob, run) = bc::bc_from_source(g, src, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: Some(src),
            output: Output::Bc { scores: prob.bc_values },
            run,
            iterations: None,
        })
    }
}

impl Primitive for PageRank {
    const KIND: PrimitiveKind = PrimitiveKind::PageRank;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (prob, run) = if req.params.pull {
            pagerank::pagerank_pull(g, cfg)
        } else {
            pagerank::pagerank(g, cfg)
        };
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::PageRank { ranks: prob.ranks, iterations: prob.iterations },
            run,
            iterations: None,
        })
    }
}

impl Primitive for Cc {
    const KIND: PrimitiveKind = PrimitiveKind::Cc;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (prob, run) = cc::cc(g, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::Cc { component: prob.component, num_components: prob.num_components },
            run,
            iterations: None,
        })
    }
}

impl Primitive for Tc {
    const KIND: PrimitiveKind = PrimitiveKind::Tc;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (res, run) = tc::tc_intersect_filtered(g, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::Tc { triangles: res.triangles },
            run,
            iterations: None,
        })
    }
}

impl Primitive for Wtf {
    const KIND: PrimitiveKind = PrimitiveKind::Wtf;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        let user = validate(g, req)?.expect("wtf needs a user");
        let (res, run) = wtf::wtf(g, user, req.params.cot_size, req.params.num_recs, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: Some(user),
            output: Output::Wtf {
                recommendations: res.recommendations,
                circle_of_trust: res.circle_of_trust,
                scores: res.ppr_scores,
            },
            run,
            iterations: None,
        })
    }
}

impl Primitive for Ppr {
    const KIND: PrimitiveKind = PrimitiveKind::Ppr;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        let user = validate(g, req)?.expect("ppr needs a user");
        // One lane of the batch engine: single-user PPR and service
        // batches share one code path (and therefore one numeric
        // behavior) by construction.
        let mut responses = Self::run_batch(g, &[user], req, cfg)?;
        Ok(responses.pop().expect("one source, one response"))
    }

    fn run_batch<G: GraphRep>(
        g: &G,
        sources: &[VertexId],
        req: &Request,
        cfg: &Config,
    ) -> Result<Vec<Response>, QueryError> {
        validate_batch(g, sources, req)?;
        let mut out = Vec::with_capacity(sources.len());
        for chunk in sources.chunks(LANES) {
            let (cols, run) =
                wtf::ppr_batch(g, chunk, req.params.ppr_iters, req.params.ppr_damping, cfg);
            for (&user, col) in chunk.iter().zip(cols) {
                let recommendations = wtf::circle_of_trust(&col, user, req.params.num_recs);
                out.push(Response {
                    kind: Self::KIND,
                    source: Some(user),
                    output: Output::Ppr { scores: col, recommendations },
                    run: run.clone(),
                    iterations: None,
                });
            }
        }
        Ok(out)
    }
}

impl Primitive for Mst {
    const KIND: PrimitiveKind = PrimitiveKind::Mst;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (res, run) = mst::mst(g, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::Mst {
                tree_edges: res.tree_edges.len(),
                total_weight: res.total_weight,
            },
            run,
            iterations: None,
        })
    }
}

impl Primitive for ColorPrim {
    const KIND: PrimitiveKind = PrimitiveKind::Color;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (res, run) = color::color(g, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::Color { num_colors: res.num_colors },
            run,
            iterations: None,
        })
    }
}

impl Primitive for Mis {
    const KIND: PrimitiveKind = PrimitiveKind::Mis;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (in_mis, run) = color::mis(g, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::Mis { size: in_mis.iter().filter(|&&b| b).count() },
            run,
            iterations: None,
        })
    }
}

impl Primitive for Lp {
    const KIND: PrimitiveKind = PrimitiveKind::Lp;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (res, run) = label_propagation::label_propagation(g, cfg);
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::Lp {
                num_communities: res.num_communities,
                iterations: res.iterations,
            },
            run,
            iterations: None,
        })
    }
}

impl Primitive for Radii {
    const KIND: PrimitiveKind = PrimitiveKind::Radii;

    fn run<G: GraphRep>(g: &G, req: &Request, cfg: &Config) -> Result<Response, QueryError> {
        validate(g, req)?;
        let (radius, eccentricities) =
            traversal_extras::estimate_radius(g, req.params.radii_samples, cfg, cfg.seed);
        // The radius estimator aggregates its sample BFS runs internally
        // and reports no per-run stats; each sample BFS honours the
        // budget on its own, so re-check here to surface a trip.
        let mut run = RunResult::default();
        run.interrupted = cfg.budget.check(0);
        Ok(Response {
            kind: Self::KIND,
            source: None,
            output: Output::Radii { radius, eccentricities },
            run,
            iterations: None,
        })
    }
}

/// Merge the request's own budget into the config: the tighter of both
/// wins, so a service-wide deadline and a per-request deadline compose.
fn effective_config(req: &Request, cfg: &Config) -> Config {
    if req.params.budget.is_unlimited() {
        return cfg.clone();
    }
    let mut out = cfg.clone();
    out.budget = cfg.budget.merge(&req.params.budget);
    out
}

/// Feed one engine run into the metrics registry (no-op when obs is
/// disabled). Called once per underlying engine invocation, never per
/// lane, so batch counters reflect traversals actually executed.
fn feed_obs(kind: PrimitiveKind, run: &RunResult) {
    obs::record_run(
        kind.tag(),
        run.runtime_ms,
        run.edges_visited,
        run.num_iterations() as u64,
        run.lanes.max(1) as u64,
        run.warp_efficiency,
        run.kernel_launches,
        run.atomics,
        run.interrupted.is_some(),
    );
}

/// Map a budget trip recorded by the enactor into the typed error the
/// caller sees, carrying the partial-progress counters.
fn interrupted_to_error(run: &RunResult) -> Option<QueryError> {
    run.interrupted.map(|i| match i {
        Interrupt::Deadline => QueryError::DeadlineExceeded {
            elapsed_ms: run.runtime_ms as u64,
            completed_iterations: run.num_iterations(),
        },
        Interrupt::Cancelled => QueryError::Cancelled {
            completed_iterations: run.num_iterations(),
        },
        Interrupt::IterationBudget => QueryError::IterationLimit {
            completed_iterations: run.num_iterations(),
        },
    })
}

/// Run one request — the single dispatch point every caller goes through.
/// A budget trip mid-run comes back as a typed error with the partial
/// progress made, not as a silently truncated answer.
pub fn run_request<G: GraphRep>(
    g: &G,
    req: &Request,
    cfg: &Config,
) -> Result<Response, QueryError> {
    let cfg = effective_config(req, cfg);
    let cfg = &cfg;
    let mut resp = match req.kind {
        PrimitiveKind::Bfs => Bfs::run(g, req, cfg),
        PrimitiveKind::Sssp => Sssp::run(g, req, cfg),
        PrimitiveKind::Bc => Bc::run(g, req, cfg),
        PrimitiveKind::PageRank => PageRank::run(g, req, cfg),
        PrimitiveKind::Cc => Cc::run(g, req, cfg),
        PrimitiveKind::Tc => Tc::run(g, req, cfg),
        PrimitiveKind::Wtf => Wtf::run(g, req, cfg),
        PrimitiveKind::Ppr => Ppr::run(g, req, cfg),
        PrimitiveKind::Mst => Mst::run(g, req, cfg),
        PrimitiveKind::Color => ColorPrim::run(g, req, cfg),
        PrimitiveKind::Mis => Mis::run(g, req, cfg),
        PrimitiveKind::Lp => Lp::run(g, req, cfg),
        PrimitiveKind::Radii => Radii::run(g, req, cfg),
    }?;
    resp.iterations = IterationSummary::from_run(&resp.run);
    feed_obs(req.kind, &resp.run);
    match interrupted_to_error(&resp.run) {
        Some(e) => Err(e),
        None => Ok(resp),
    }
}

/// Run one request over many sources: lane-batchable kinds go through
/// their bit-parallel engines (in chunks of up to 64), everything else
/// runs sequentially per source. One response per source, in order.
pub fn run_batch<G: GraphRep>(
    g: &G,
    sources: &[VertexId],
    req: &Request,
    cfg: &Config,
) -> Result<Vec<Response>, QueryError> {
    crate::util::faults::maybe_panic_sources(sources);
    let cfg = effective_config(req, cfg);
    let cfg = &cfg;
    let mut responses = match req.kind {
        PrimitiveKind::Bfs => Bfs::run_batch(g, sources, req, cfg),
        PrimitiveKind::Sssp => Sssp::run_batch(g, sources, req, cfg),
        PrimitiveKind::Bc => Bc::run_batch(g, sources, req, cfg),
        PrimitiveKind::PageRank => PageRank::run_batch(g, sources, req, cfg),
        PrimitiveKind::Cc => Cc::run_batch(g, sources, req, cfg),
        PrimitiveKind::Tc => Tc::run_batch(g, sources, req, cfg),
        PrimitiveKind::Wtf => Wtf::run_batch(g, sources, req, cfg),
        PrimitiveKind::Ppr => Ppr::run_batch(g, sources, req, cfg),
        PrimitiveKind::Mst => Mst::run_batch(g, sources, req, cfg),
        PrimitiveKind::Color => ColorPrim::run_batch(g, sources, req, cfg),
        PrimitiveKind::Mis => Mis::run_batch(g, sources, req, cfg),
        PrimitiveKind::Lp => Lp::run_batch(g, sources, req, cfg),
        PrimitiveKind::Radii => Radii::run_batch(g, sources, req, cfg),
    }?;
    for r in &mut responses {
        r.iterations = IterationSummary::from_run(&r.run);
    }
    // Lane-mates share one engine run (`run.lanes` clones of it), so
    // step by the lane width to feed each underlying traversal once.
    let mut i = 0;
    while i < responses.len() {
        feed_obs(req.kind, &responses[i].run);
        i += responses[i].run.lanes.max(1);
    }
    // Lane-batched kinds share one traversal per chunk, so a budget trip
    // anywhere fails the whole call; the service layer decides which
    // members actually expired and re-runs the rest.
    match responses.iter().find_map(|r| interrupted_to_error(&r.run)) {
        Some(e) => Err(e),
        None => Ok(responses),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder;

    fn path5() -> crate::graph::Csr {
        builder::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)])
    }

    #[test]
    fn kind_parses_and_round_trips() {
        for s in [
            "bfs", "sssp", "bc", "pagerank", "cc", "tc", "wtf", "ppr", "mst", "color", "mis",
            "lp", "radii",
        ] {
            let k: PrimitiveKind = s.parse().unwrap();
            assert_eq!(k.to_string(), s, "{s}");
        }
        assert_eq!("pr".parse::<PrimitiveKind>().unwrap(), PrimitiveKind::PageRank);
        assert!(matches!(
            "bogus".parse::<PrimitiveKind>(),
            Err(QueryError::UnknownPrimitive(_))
        ));
    }

    #[test]
    fn kind_tags_match_obs_names() {
        for s in [
            "bfs", "sssp", "bc", "pagerank", "cc", "tc", "wtf", "ppr", "mst", "color", "mis",
            "lp", "radii",
        ] {
            let k: PrimitiveKind = s.parse().unwrap();
            assert_eq!(crate::obs::prim_name(k.tag()), k.to_string(), "{s}");
        }
    }

    #[test]
    fn response_carries_iteration_summary() {
        let g = path5(); // BFS from 0 needs 4 push iterations
        let resp = run_request(&g, &Request::with_source(PrimitiveKind::Bfs, 0), &Config::default())
            .unwrap();
        let summary = resp.iterations.expect("bfs records an iteration trail");
        assert_eq!(summary.count, resp.run.num_iterations());
        assert_eq!(summary.push + summary.pull, summary.count);
        assert!(summary.max_frontier >= 1);
        assert_eq!(summary.edges, resp.run.iterations.iter().map(|i| i.edges_this_iter).sum());
        // A summary is never zero-filled: a kind that records no
        // iteration trail gets None, not a count-0 summary.
        let tc = run_request(&g, &Request::new(PrimitiveKind::Tc), &Config::default()).unwrap();
        if let Some(s) = tc.iterations {
            assert!(s.count > 0, "summary present implies a non-empty trail");
        }
    }

    #[test]
    fn batch_responses_carry_iteration_summaries() {
        let g = path5();
        let resps =
            run_batch(&g, &[0, 1, 2], &Request::new(PrimitiveKind::Bfs), &Config::default())
                .unwrap();
        for r in &resps {
            let s = r.iterations.expect("batched bfs records iterations");
            assert_eq!(s.count, r.run.num_iterations());
        }
    }

    #[test]
    fn run_request_matches_direct_call() {
        let g = path5();
        let cfg = Config::default();
        let resp = run_request(&g, &Request::with_source(PrimitiveKind::Bfs, 0), &cfg).unwrap();
        let (want, _) = bfs::bfs(&g, 0, &cfg);
        match resp.output {
            Output::Bfs { labels, .. } => assert_eq!(labels, want.labels),
            other => panic!("wrong output variant {other:?}"),
        }
        assert_eq!(resp.source, Some(0));
        assert_eq!(resp.run.lanes, 1);
    }

    #[test]
    fn invalid_source_is_an_error_value() {
        let g = path5();
        let err = run_request(&g, &Request::with_source(PrimitiveKind::Bfs, 99), &Config::default())
            .unwrap_err();
        assert_eq!(err, QueryError::InvalidSource { source: 99, num_vertices: 5 });
    }

    #[test]
    fn weightless_sssp_is_an_error_value() {
        let g = path5();
        let err = run_request(&g, &Request::with_source(PrimitiveKind::Sssp, 0), &Config::default())
            .unwrap_err();
        assert_eq!(err, QueryError::NeedsWeights { primitive: PrimitiveKind::Sssp });
    }

    #[test]
    fn pull_pagerank_without_in_edges_is_an_error_value() {
        use crate::graph::{Codec, CompressedCsr};
        let cg = CompressedCsr::from_csr(&path5(), Codec::Varint); // push-only
        let mut req = Request::new(PrimitiveKind::PageRank);
        req.params.pull = true;
        let err = run_request(&cg, &req, &Config::default()).unwrap_err();
        assert_eq!(err, QueryError::NeedsInEdges { what: "pull PageRank" });
    }

    #[test]
    fn default_source_is_max_degree_vertex() {
        let g = builder::from_edges(4, &[(2, 0), (2, 1), (2, 3), (0, 1)]);
        let resp =
            run_request(&g, &Request::new(PrimitiveKind::Bfs), &Config::default()).unwrap();
        assert_eq!(resp.source, Some(2));
    }

    #[test]
    fn batch_chunks_past_lane_width() {
        let g = path5();
        let sources: Vec<u32> = (0..70).map(|i| i % 5).collect();
        let req = Request::new(PrimitiveKind::Bfs);
        let resps = run_batch(&g, &sources, &req, &Config::default()).unwrap();
        assert_eq!(resps.len(), 70);
        let (want, _) = bfs::bfs(&g, 3, &Config::default());
        for resp in resps.iter().filter(|r| r.source == Some(3)) {
            match &resp.output {
                Output::Bfs { labels, .. } => assert_eq!(labels, &want.labels),
                other => panic!("wrong output variant {other:?}"),
            }
            assert!(resp.run.lanes > 1, "batched responses carry the lane count");
        }
    }

    #[test]
    fn non_batchable_kind_falls_back_to_sequential() {
        let g = path5();
        let req = Request::new(PrimitiveKind::Bc);
        let resps = run_batch(&g, &[0, 1], &req, &Config::default()).unwrap();
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0].source, Some(0));
        assert_eq!(resps[1].source, Some(1));
        assert!(resps.iter().all(|r| r.run.lanes == 1));
    }

    #[test]
    fn expired_deadline_is_a_typed_error_with_progress() {
        let g = path5();
        let mut req = Request::with_source(PrimitiveKind::Bfs, 0);
        req.params.budget = RunBudget {
            deadline: Some(std::time::Instant::now() - std::time::Duration::from_millis(5)),
            ..RunBudget::default()
        };
        match run_request(&g, &req, &Config::default()) {
            Err(QueryError::DeadlineExceeded { completed_iterations, .. }) => {
                // the trip fires at the first iteration boundary
                assert!(completed_iterations <= 1, "trip bounded by one BSP iteration");
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn pre_cancelled_request_is_a_typed_error() {
        use crate::util::budget::CancelToken;
        let mut g = path5();
        crate::graph::datasets::attach_uniform_weights(&mut g, 7);
        let token = CancelToken::new();
        token.cancel();
        let mut req = Request::with_source(PrimitiveKind::Sssp, 0);
        req.params.budget = RunBudget::with_cancel(token);
        match run_request(&g, &req, &Config::default()) {
            Err(QueryError::Cancelled { completed_iterations }) => {
                assert!(completed_iterations <= 1);
            }
            other => panic!("expected Cancelled, got {other:?}"),
        }
    }

    #[test]
    fn iteration_budget_is_reported_not_silent() {
        // path graph needs 4 BFS iterations; cap the budget at 1
        let g = path5();
        let mut req = Request::with_source(PrimitiveKind::Bfs, 0);
        req.params.budget = RunBudget { max_iterations: Some(1), ..RunBudget::default() };
        match run_request(&g, &req, &Config::default()) {
            Err(QueryError::IterationLimit { completed_iterations }) => {
                assert_eq!(completed_iterations, 1);
            }
            other => panic!("expected IterationLimit, got {other:?}"),
        }
        // ...while the engine's own max_iters cap stays a silent finish
        let mut cfg = Config::default();
        cfg.max_iters = 1;
        let req = Request::with_source(PrimitiveKind::Bfs, 0);
        let resp = run_request(&g, &req, &cfg).unwrap();
        assert!(resp.run.interrupted.is_none());
    }

    #[test]
    fn budget_trip_fails_the_whole_lane_batch() {
        let g = path5();
        let mut req = Request::new(PrimitiveKind::Bfs);
        req.params.budget = RunBudget { max_iterations: Some(1), ..RunBudget::default() };
        let err = run_batch(&g, &[0, 1, 2], &req, &Config::default()).unwrap_err();
        assert!(matches!(err, QueryError::IterationLimit { .. }), "{err:?}");
    }

    #[test]
    fn generous_budget_changes_nothing() {
        let g = path5();
        let mut req = Request::with_source(PrimitiveKind::Bfs, 0);
        req.params.budget = RunBudget::with_deadline_ms(60_000);
        let resp = run_request(&g, &req, &Config::default()).unwrap();
        let (want, _) = bfs::bfs(&g, 0, &Config::default());
        match resp.output {
            Output::Bfs { labels, .. } => assert_eq!(labels, want.labels),
            other => panic!("wrong output variant {other:?}"),
        }
    }
}
