//! Label propagation community detection (paper §5.1.5 / §8.2 mention LP
//! among the primitives that benefit from frontier reorganization): each
//! vertex repeatedly adopts the most frequent label among its neighbors;
//! vertices whose label changed re-activate their neighborhood.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::{Frontier, FrontierKind};
use crate::graph::{GraphRep, VertexId};
use crate::operators::compute;
use crate::util::bitset::AtomicBitset;
use crate::util::timer::Timer;

pub struct LpResult {
    pub labels: Vec<u32>,
    pub num_communities: usize,
    pub iterations: usize,
}

/// Generic over the graph representation (neighborhood label counts
/// decode on the fly; no neighbor slices are materialized).
pub fn label_propagation<G: GraphRep>(g: &G, config: &Config) -> (LpResult, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::LP, 1);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let labels: Vec<AtomicU32> = (0..n).map(|v| AtomicU32::new(v as u32)).collect();
    // Full dense start (O(n/64)); the hybrid engine demotes to a queue
    // once re-activation narrows.
    let mut frontier = Frontier::all_vertices(n);
    if !enactor.densify_plain(n, n) {
        frontier.to_sparse();
    }
    // Reused across rounds: changed-vertex bitmap and the dense next
    // frontier (its fetch_or insertion replaces the old `seen` dedup set
    // — a bitmap frontier deduplicates by construction).
    let changed = AtomicBitset::new(n);
    let mut next = Frontier::dense_empty(FrontierKind::Vertex, n);
    let mut iters = 0usize;
    let max_rounds = config.max_iters.min(100);

    while !frontier.is_empty() && iters < max_rounds && enactor.budget_ok() {
        let t = Timer::start();
        iters += 1;
        let input_len = frontier.len();
        changed.clear_all();
        let ctx = enactor.ctx();
        let counters = &enactor.counters;

        // adopt the plurality label of the neighborhood (ties -> smaller
        // label, for determinism)
        let update = |v: VertexId| {
            let deg = g.degree(v);
            counters.add_edges(deg as u64);
            if deg == 0 {
                return;
            }
            let mut counts: HashMap<u32, u32> = HashMap::with_capacity(deg);
            g.for_each_neighbor(v, |_, u| {
                *counts.entry(labels[u as usize].load(Ordering::Relaxed)).or_insert(0) += 1;
            });
            let (&best, _) = counts
                .iter()
                .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
                .unwrap();
            let old = labels[v as usize].swap(best, Ordering::Relaxed);
            if old != best {
                changed.set(v as usize);
            }
        };
        compute::compute(&ctx, &frontier, update);

        // next frontier: vertices adjacent to a change (plus the changed)
        // — inserted straight into the recycled dense bitmap.
        next.reset_dense(FrontierKind::Vertex, n);
        for v in changed.iter_set() {
            next.push(v as VertexId);
            g.for_each_neighbor(v as VertexId, |_, u| {
                next.push(u);
            });
        }
        if !enactor.densify_plain(n, next.len()) {
            next.to_sparse();
        }
        std::mem::swap(&mut frontier, &mut next);
        enactor.record_iteration(input_len, frontier.len(), t.elapsed_ms(), false);
    }

    let labels: Vec<u32> = labels.into_iter().map(|a| a.into_inner()).collect();
    let mut uniq = labels.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let result = enactor.finish_run();
    (LpResult { labels, num_communities: uniq.len(), iterations: iters }, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder, Csr};

    /// Two dense cliques joined by one bridge edge.
    fn two_cliques(k: usize) -> Csr {
        let mut edges = Vec::new();
        for a in 0..k as u32 {
            for b in a + 1..k as u32 {
                edges.push((a, b));
                edges.push((k as u32 + a, k as u32 + b));
            }
        }
        edges.push((0, k as u32));
        builder::undirected_from_edges(2 * k, &edges)
    }

    #[test]
    fn cliques_form_communities() {
        let g = two_cliques(8);
        let (r, _) = label_propagation(&g, &Config::default());
        // all members of clique 1 share a label; same for clique 2
        for v in 1..8 {
            assert_eq!(r.labels[v], r.labels[1], "clique A not uniform");
        }
        for v in 9..16 {
            assert_eq!(r.labels[v], r.labels[9], "clique B not uniform");
        }
        assert!(r.num_communities <= 3);
    }

    #[test]
    fn converges_and_terminates() {
        let g = two_cliques(5);
        let (r, run) = label_propagation(&g, &Config::default());
        assert!(r.iterations < 100);
        assert!(run.num_iterations() == r.iterations);
    }

    #[test]
    fn isolated_vertices_keep_own_label() {
        let g = builder::from_edges(3, &[]);
        let (r, _) = label_propagation(&g, &Config::default());
        assert_eq!(r.labels, vec![0, 1, 2]);
        assert_eq!(r.num_communities, 3);
    }
}
