//! Minimum spanning tree / forest via Borůvka with supervertex forming —
//! the paper's MST primitive (§8.2.3: "In our current minimum-spanning-
//! tree primitive, we have implemented a supervertex-forming phase using
//! a series of filter, advance, sort, and prefix-sum").
//!
//! Each round: (1) neighborhood-reduce per component to find the minimum
//! outgoing edge; (2) hook components along those edges (cycle-breaking
//! by id); (3) pointer-jump to collapse the supervertex forest; until no
//! component has an outgoing edge.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::graph::{GraphRep, VertexId};
use crate::util::budget::BudgetProbe;
use crate::util::par;
use crate::util::timer::Timer;

pub struct MstResult {
    /// Edge ids (into the CSR) selected into the forest.
    pub tree_edges: Vec<usize>,
    pub total_weight: u64,
    /// Supervertex (component) label per vertex after convergence.
    pub component: Vec<u32>,
}

/// Borůvka MST on an undirected weighted graph (each edge stored in both
/// directions; ties broken by edge id so both directions agree).
///
/// Generic over the graph representation: the min-outgoing-edge scan
/// streams every neighbor list (decode-on-scan for compressed graphs) and
/// candidates carry their destination, so no phase random-accesses edges
/// by id.
pub fn mst<G: GraphRep>(g: &G, config: &Config) -> (MstResult, RunResult) {
    assert!(g.is_weighted(), "MST needs edge weights");
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::MST, 1);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let comp: Vec<AtomicU32> = (0..n).map(|v| AtomicU32::new(v as u32)).collect();
    let mut tree_edges: Vec<usize> = Vec::new();
    let mut total_weight = 0u64;
    // The candidate scan is the long pole of a round, so the deadline is
    // also polled inside it (amortized probe shared by all workers); a
    // trip discards the round's partial candidates and stops cleanly.
    let probe = BudgetProbe::new(&config.budget);

    loop {
        let t = Timer::start();
        let label = |v: VertexId| comp[v as usize].load(Ordering::Relaxed);

        // (1) min outgoing edge per component: scan all vertices' edges in
        // parallel, reduce per source component. Candidates are ordered by
        // (weight, canonical undirected endpoints, edge id) — a globally
        // consistent total order on *undirected* edges, which guarantees
        // the component pointer graph has only 2-cycles (mutual minima),
        // the classical Boruvka cycle-safety argument. Each candidate
        // records its destination vertex at scan time, so the hook phase
        // never random-accesses an edge id (a decode on compressed reps).
        type Cand = (u32, u32, u32, usize, VertexId); // (w, min_end, max_end, eid, dst)
        let candidates = par::run_partitioned(n, enactor.workers, |_, s, e| {
            let mut local: std::collections::HashMap<u32, Cand> = std::collections::HashMap::new();
            for v in s..e {
                if !probe.poll() {
                    break;
                }
                let v = v as VertexId;
                let cv = label(v);
                g.for_each_neighbor(v, |eid, u| {
                    if label(u) == cv {
                        return; // internal edge
                    }
                    let cand: Cand = (g.weight(eid), v.min(u), v.max(u), eid, u);
                    let entry = local.entry(cv).or_insert(cand);
                    if (cand.0, cand.1, cand.2) < (entry.0, entry.1, entry.2) {
                        *entry = cand;
                    }
                });
            }
            local
        });
        enactor.counters.add_edges(g.num_edges() as u64);
        if let Some(interrupt) = probe.tripped() {
            // partial candidates must not be hooked — drop the round
            enactor.note_interrupt(interrupt);
            enactor.record_iteration(n, 0, t.elapsed_ms(), false);
            break;
        }
        let mut best: std::collections::HashMap<u32, Cand> = std::collections::HashMap::new();
        for chunk in candidates {
            for (c, cand) in chunk {
                let entry = best.entry(c).or_insert(cand);
                if (cand.0, cand.1, cand.2) < (entry.0, entry.1, entry.2) {
                    *entry = cand;
                }
            }
        }
        if best.is_empty() {
            enactor.record_iteration(n, 0, t.elapsed_ms(), false);
            break;
        }

        // (2) hook along the chosen edges. All (src_comp, dst_comp) pairs
        // are resolved against the labels at the START of the round (the
        // BSP snapshot) — resolving against in-round stores would see a
        // partner's hook and double-add mutual edges. Mutual minima (both
        // components selected the same undirected edge) would form a
        // 2-cycle: only the lower-labelled component performs that hook.
        let hooks: Vec<(u32, u32, u32, usize)> = best
            .iter()
            .map(|(&c, &(w, _a, _b, eid, dst))| {
                let dst_comp = label(dst);
                (c, dst_comp, w, eid)
            })
            .collect();
        let mut added = 0usize;
        for &(src_comp, dst_comp, w, eid) in &hooks {
            debug_assert_ne!(src_comp, dst_comp);
            let (w1, a1, b1, _, _) = best[&src_comp];
            let mutual = best
                .get(&dst_comp)
                .map(|&(w2, a2, b2, _, _)| (w2, a2, b2) == (w1, a1, b1))
                .unwrap_or(false);
            let _ = w1;
            if mutual && src_comp > dst_comp {
                continue; // the lower component performs the hook
            }
            comp[src_comp as usize].store(dst_comp, Ordering::Relaxed);
            tree_edges.push(eid);
            total_weight += w as u64;
            added += 1;
        }

        // (3) pointer-jump to collapse supervertices.
        loop {
            let mut changed = false;
            for v in 0..n {
                let c = comp[v].load(Ordering::Relaxed);
                let cc = comp[c as usize].load(Ordering::Relaxed);
                if c != cc {
                    comp[v].store(cc, Ordering::Relaxed);
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }

        enactor.record_iteration(n, added, t.elapsed_ms(), false);
        if added == 0 || !enactor.proceed() {
            break;
        }
    }

    let component: Vec<u32> = comp.into_iter().map(|a| a.into_inner()).collect();
    let result = enactor.finish_run();
    (MstResult { tree_edges, total_weight, component }, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder, Coo, Csr};

    fn weighted_undirected(n: usize, edges: &[(u32, u32, u32)]) -> Csr {
        let mut coo = Coo::new(n);
        for &(s, d, w) in edges {
            coo.push_weighted(s, d, w);
            coo.push_weighted(d, s, w);
        }
        builder::from_coo(&coo, true)
    }

    /// Serial Kruskal oracle.
    fn kruskal_weight(n: usize, edges: &[(u32, u32, u32)]) -> u64 {
        let mut es: Vec<_> = edges.to_vec();
        es.sort_by_key(|e| e.2);
        let mut parent: Vec<u32> = (0..n as u32).collect();
        fn find(p: &mut Vec<u32>, v: u32) -> u32 {
            let mut v = v;
            while p[v as usize] != v {
                p[v as usize] = p[p[v as usize] as usize];
                v = p[v as usize];
            }
            v
        }
        let mut total = 0u64;
        for (s, d, w) in es {
            let (rs, rd) = (find(&mut parent, s), find(&mut parent, d));
            if rs != rd {
                parent[rs as usize] = rd;
                total += w as u64;
            }
        }
        total
    }

    #[test]
    fn simple_mst_weight() {
        let edges = [(0, 1, 1), (1, 2, 2), (0, 2, 10), (2, 3, 3)];
        let g = weighted_undirected(4, &edges);
        let (r, _) = mst(&g, &Config::default());
        assert_eq!(r.total_weight, 6); // 1 + 2 + 3
        assert_eq!(r.tree_edges.len(), 3);
    }

    #[test]
    fn forest_on_disconnected_graph() {
        let edges = [(0, 1, 4), (2, 3, 7)];
        let g = weighted_undirected(5, &edges);
        let (r, _) = mst(&g, &Config::default());
        assert_eq!(r.total_weight, 11);
        assert_eq!(r.tree_edges.len(), 2);
        // components: {0,1}, {2,3}, {4}
        let mut roots: Vec<u32> = r.component.clone();
        roots.sort_unstable();
        roots.dedup();
        assert_eq!(roots.len(), 3);
    }

    #[test]
    fn matches_kruskal_on_random_graphs() {
        use crate::util::rng::Pcg32;
        for seed in 0..6u64 {
            let mut rng = Pcg32::new(seed);
            let n = 40 + rng.below_usize(60);
            let mut edges = Vec::new();
            let mut seen = std::collections::HashSet::new();
            for _ in 0..n * 3 {
                let s = rng.below(n as u32);
                let d = rng.below(n as u32);
                if s == d {
                    continue;
                }
                let key = (s.min(d), s.max(d));
                if !seen.insert(key) {
                    continue;
                }
                edges.push((key.0, key.1, rng.weight(1, 100)));
            }
            let g = weighted_undirected(n, &edges);
            let (r, _) = mst(&g, &Config::default());
            assert_eq!(r.total_weight, kruskal_weight(n, &edges), "seed {seed}");
        }
    }
}
