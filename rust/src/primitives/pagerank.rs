//! PageRank (paper §6.5): full-vertex frontier, per iteration an advance
//! accumulates rank contributions (atomicAdd) and a filter retires
//! converged vertices. Also exposes a pull-mode (CSC gather, atomic-free)
//! variant over the in-edge view.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::operators::{advance, filter, neighborhood_reduce};
use crate::util::timer::Timer;

pub struct PageRankProblem {
    pub ranks: Vec<f64>,
    pub iterations: usize,
}

/// f64 atomic add via u64-bits CAS (the GPU's atomicAdd analog).
#[inline]
fn atomic_add_f64(slot: &AtomicU64, add: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + add;
        match slot.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Push-mode PageRank: scatter rank/deg contributions along out-edges.
///
/// Generic over the graph representation — runs over raw CSR or the
/// compressed gap-encoded payload through the same advance pipeline. With
/// equal worker counts the per-edge visit order matches between
/// representations, so single-threaded runs are bit-identical.
pub fn pagerank<G: GraphRep>(g: &G, config: &Config) -> (PageRankProblem, RunResult) {
    let _span =
        crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::PAGERANK, 1);
    let n = g.num_vertices();
    let damp = config.pr_damping;
    let eps = config.pr_epsilon;
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let mut ranks: Vec<f64> = vec![1.0 / n as f64; n];
    // Full-vertex scatter frontier, hoisted out of the loop: a filled
    // bitmap (O(n/64) to build, word-swept by the advance — no 0..n id
    // materialization per iteration). The convergence frontier starts
    // identical and shrinks; the hybrid engine demotes it to a queue
    // once occupancy drops.
    let mut full = Frontier::all_vertices(n);
    if !enactor.densify_output(g, n) {
        full.to_sparse();
    }
    let mut frontier = Frontier::all_vertices(n);
    if !enactor.densify_plain(n, n) {
        frontier.to_sparse();
    }
    let mut iters = 0usize;

    while !frontier.is_empty() && iters < config.pr_max_iters && enactor.budget_ok() {
        let t = Timer::start();
        iters += 1;
        let next: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();

        // Dangling mass (zero-out-degree vertices redistribute uniformly).
        let dangling: f64 = (0..n as VertexId)
            .filter(|&v| g.degree(v) == 0)
            .map(|v| ranks[v as usize])
            .sum();

        let strategy = enactor.strategy_for(g, frontier.len());
        let ctx = enactor.ctx();
        // Hoist the per-source division out of the per-edge path (§Perf):
        // shares[v] = rank(v)/outdeg(v), computed once per iteration.
        let shares: Vec<f64> = (0..n)
            .map(|v| {
                let d = g.degree(v as VertexId);
                if d == 0 { 0.0 } else { ranks[v] / d as f64 }
            })
            .collect();
        let shares_ref = &shares;
        // Advance over the full frontier: each edge scatters src rank.
        let scatter = |s: VertexId, d: VertexId, _e: usize| {
            atomic_add_f64(&next[d as usize], shares_ref[s as usize]);
            false // no output frontier from the advance itself
        };
        advance::advance(&ctx, g, &full, advance::AdvanceType::V2V, strategy, &scatter);
        // one accumulation atomic per edge (batched stat)
        enactor.counters.add_atomics(g.num_edges() as u64);

        let base = (1.0 - damp) / n as f64 + damp * dangling / n as f64;
        let new_ranks: Vec<f64> =
            next.iter().map(|a| base + damp * f64::from_bits(a.load(Ordering::Relaxed))).collect();

        // Filter: keep only unconverged vertices in the frontier (the
        // paper removes "vertices whose PageRanks have already converged").
        let old_ranks = std::mem::replace(&mut ranks, new_ranks);
        let input_len = frontier.len();
        let ranks_now = &ranks;
        let keep = |v: VertexId| (ranks_now[v as usize] - old_ranks[v as usize]).abs() > eps;
        let mut next_frontier = filter::filter(&ctx, &frontier, &keep);
        // Demote once few unconverged vertices remain (pure id set — the
        // occupancy rule, not the expansion estimate).
        if next_frontier.is_dense() && !enactor.densify_plain(n, next_frontier.len()) {
            next_frontier.to_sparse();
        }

        enactor.record_iteration(input_len, next_frontier.len(), t.elapsed_ms(), false);
        frontier = next_frontier;
    }

    let result = enactor.finish_run();
    (PageRankProblem { ranks, iterations: iters }, result)
}

/// Pull-mode PageRank: gather over in-neighbors (atomic-free, the
/// neighborhood-reduce operator) — the mode the AOT ELL artifact mirrors.
/// The contribution buffer is enactor-lifetime scratch reused across
/// iterations (`in_neighborhood_reduce_into`): a warm iteration performs
/// no rank-sized allocation beyond the new-ranks vector itself.
///
/// Generic over the representation; requires an in-edge view (the CSC
/// arrays on raw CSR, the compressed in-edge streams on `.gsr` graphs).
pub fn pagerank_pull<G: GraphRep>(g: &G, config: &Config) -> (PageRankProblem, RunResult) {
    assert!(g.has_in_edges(), "pull PageRank requires an in-edge view");
    let _span =
        crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::PAGERANK, 1);
    let n = g.num_vertices();
    let damp = config.pr_damping;
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let mut ranks: Vec<f64> = vec![1.0 / n as f64; n];
    let all: Vec<VertexId> = (0..n as VertexId).collect();
    let mut contribs: Vec<f64> = Vec::new();
    let mut iters = 0usize;
    loop {
        if !enactor.budget_ok() {
            break;
        }
        let t = Timer::start();
        iters += 1;
        let dangling: f64 = (0..n as VertexId)
            .filter(|&v| g.degree(v) == 0)
            .map(|v| ranks[v as usize])
            .sum();
        let ctx = enactor.ctx();
        let ranks_ref = &ranks;
        neighborhood_reduce::in_neighborhood_reduce_into(
            &ctx,
            g,
            &all,
            0.0f64,
            |_v, u| ranks_ref[u as usize] / g.degree(u) as f64,
            |a, b| a + b,
            &mut contribs,
        );
        let base = (1.0 - damp) / n as f64 + damp * dangling / n as f64;
        let new_ranks: Vec<f64> = contribs.iter().map(|c| base + damp * c).collect();
        let delta: f64 =
            new_ranks.iter().zip(&ranks).map(|(a, b)| (a - b).abs()).sum();
        ranks = new_ranks;
        enactor.record_iteration(n, n, t.elapsed_ms(), true);
        if delta < config.pr_epsilon * n as f64 || iters >= config.pr_max_iters {
            break;
        }
    }
    let result = enactor.finish_run();
    (PageRankProblem { ranks, iterations: iters }, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::pagerank_serial::pagerank_serial;
    use crate::graph::builder;
    use crate::graph::generators::{rmat, rmat::RmatParams};

    fn close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn ranks_sum_to_one() {
        let g = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() });
        let (p, _) = pagerank(&g, &Config::default());
        let sum: f64 = p.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
    }

    #[test]
    fn matches_serial_reference() {
        let g = rmat(&RmatParams { scale: 8, edge_factor: 8, ..Default::default() });
        let mut cfg = Config::default();
        cfg.pr_max_iters = 30;
        let (p, _) = pagerank(&g, &cfg);
        let want = pagerank_serial(&g, cfg.pr_damping, 30, cfg.pr_epsilon);
        close(&p.ranks, &want, 1e-6);
    }

    #[test]
    fn pull_matches_push() {
        let g = rmat(&RmatParams { scale: 8, edge_factor: 8, ..Default::default() });
        let mut cfg = Config::default();
        cfg.pr_max_iters = 25;
        cfg.pr_epsilon = 0.0; // run all iterations in both modes
        let (push, _) = pagerank(&g, &cfg);
        let (pull, _) = pagerank_pull(&g, &cfg);
        close(&push.ranks, &pull.ranks, 1e-9);
    }

    #[test]
    fn compressed_representation_bit_identical_single_thread() {
        use crate::graph::{Codec, CompressedCsr};
        let g = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() });
        let mut cfg = Config::default();
        cfg.threads = 1; // serial visit order => identical f64 add order
        cfg.pr_max_iters = 10;
        let (want, _) = pagerank(&g, &cfg);
        let cg = CompressedCsr::from_csr(&g, Codec::Varint);
        let (got, _) = pagerank(&cg, &cfg);
        assert_eq!(want.ranks, got.ranks, "same edge order must give bit-identical ranks");
    }

    #[test]
    fn pull_over_compressed_bit_identical_to_csr_pull() {
        use crate::graph::{Codec, CompressedCsr};
        let g = rmat(&RmatParams { scale: 8, edge_factor: 8, ..Default::default() });
        let mut cfg = Config::default();
        cfg.pr_max_iters = 10;
        cfg.pr_epsilon = 0.0;
        let (want, _) = pagerank_pull(&g, &cfg);
        for codec in [Codec::Varint, Codec::Zeta(2)] {
            let cg = CompressedCsr::from_csr_with_in_edges(&g, codec);
            let (got, _) = pagerank_pull(&cg, &cfg);
            // The gather order per vertex is the sorted in-neighbor list in
            // both representations, so the f64 sums are bit-identical even
            // multi-threaded (each output slot has exactly one writer).
            assert_eq!(want.ranks, got.ranks, "{codec}");
        }
    }

    #[test]
    fn hub_ranks_highest() {
        // star: center receives all rank contributions
        let edges: Vec<(u32, u32)> = (1..=8).map(|v| (v, 0)).collect();
        let g = builder::from_edges(9, &edges);
        let (p, _) = pagerank(&g, &Config::default());
        for v in 1..9 {
            assert!(p.ranks[0] > p.ranks[v]);
        }
    }
}
