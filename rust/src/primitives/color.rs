//! Graph coloring and maximal independent set (paper §8.2.4): both built
//! from neighborhood reduction + filter in the Jones-Plassmann style —
//! each round, vertices that are local maxima of a random priority among
//! their uncolored neighbors take the smallest available color (or join
//! the MIS), then leave the frontier.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::config::Config;
use crate::enactor::{Enactor, RunResult};
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::operators::filter;
use crate::util::rng::Pcg32;
use crate::util::timer::Timer;

pub const UNCOLORED: u32 = u32::MAX;

pub struct ColoringResult {
    pub colors: Vec<u32>,
    pub num_colors: usize,
}

/// Jones-Plassmann greedy coloring over undirected graphs. Generic over
/// the graph representation (neighborhood scans decode on the fly).
pub fn color<G: GraphRep>(g: &G, config: &Config) -> (ColoringResult, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::COLOR, 1);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    // random priorities (ties by id)
    let mut rng = Pcg32::new(config.seed);
    let prio: Vec<u64> = (0..n).map(|v| (rng.next_u32() as u64) << 32 | v as u64).collect();
    let colors: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNCOLORED)).collect();

    let mut frontier = Frontier::all_vertices(n);
    if !enactor.densify_plain(n, n) {
        frontier.to_sparse();
    }
    while !frontier.is_empty() && enactor.proceed() {
        let t = Timer::start();
        let input_len = frontier.len();
        let ctx = enactor.ctx();
        let counters = &enactor.counters;

        // Local maxima among uncolored neighbors claim a color. One
        // early-exiting pass both tests the maximum and gathers the
        // colors already used around v — a disqualifying neighbor stops
        // the scan (and, on compressed graphs, the decode) immediately.
        let claim = |v: VertexId| -> bool {
            let pv = prio[v as usize];
            counters.add_edges(g.degree(v) as u64);
            let mut is_max = true;
            let mut used: Vec<u32> = Vec::new();
            g.for_each_neighbor_until(v, |_, u| {
                let c = colors[u as usize].load(Ordering::Relaxed);
                if c == UNCOLORED {
                    if prio[u as usize] >= pv {
                        is_max = false;
                        return false; // disqualified: stop scanning
                    }
                } else {
                    used.push(c);
                }
                true
            });
            if !is_max {
                return true; // stay in the frontier
            }
            // smallest color unused by colored neighbors
            used.sort_unstable();
            used.dedup();
            let mut c = 0u32;
            for &u in &used {
                if u == c {
                    c += 1;
                } else if u > c {
                    break;
                }
            }
            colors[v as usize].store(c, Ordering::Relaxed);
            false // colored: leave the frontier
        };
        frontier = filter::filter(&ctx, &frontier, &claim);
        if frontier.is_dense() && !enactor.densify_plain(n, frontier.len()) {
            frontier.to_sparse();
        }
        enactor.record_iteration(input_len, frontier.len(), t.elapsed_ms(), false);
    }

    let colors: Vec<u32> = colors.into_iter().map(|a| a.into_inner()).collect();
    let num_colors = colors.iter().filter(|&&c| c != UNCOLORED).max().map(|&m| m as usize + 1).unwrap_or(0);
    let result = enactor.finish_run();
    (ColoringResult { colors, num_colors }, result)
}

/// Maximal independent set via the same local-maxima rounds (Luby-style).
pub fn mis<G: GraphRep>(g: &G, config: &Config) -> (Vec<bool>, RunResult) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::MIS, 1);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    let mut rng = Pcg32::new(config.seed ^ 0x15);
    let prio: Vec<u64> = (0..n).map(|v| (rng.next_u32() as u64) << 32 | v as u64).collect();
    // 0 = undecided, 1 = in MIS, 2 = excluded
    let state: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();

    let mut frontier = Frontier::all_vertices(n);
    if !enactor.densify_plain(n, n) {
        frontier.to_sparse();
    }
    while !frontier.is_empty() && enactor.proceed() {
        let t = Timer::start();
        let input_len = frontier.len();
        let ctx = enactor.ctx();
        let counters = &enactor.counters;
        // Phase 1: local maxima among undecided neighbors join the MIS.
        let winners: Vec<VertexId> = frontier
            .iter()
            .filter(|&v| {
                counters.add_edges(g.degree(v) as u64);
                let mut is_max = true;
                g.for_each_neighbor_until(v, |_, u| {
                    if state[u as usize].load(Ordering::Relaxed) == 0
                        && prio[u as usize] >= prio[v as usize]
                    {
                        is_max = false;
                        return false; // disqualified: stop scanning
                    }
                    true
                });
                is_max
            })
            .collect();
        for &v in &winners {
            state[v as usize].store(1, Ordering::Relaxed);
            g.for_each_neighbor(v, |_, u| {
                let _ =
                    state[u as usize].compare_exchange(0, 2, Ordering::Relaxed, Ordering::Relaxed);
            });
        }
        // Phase 2: drop decided vertices from the frontier.
        frontier = filter::filter(&ctx, &frontier, &|v: VertexId| {
            state[v as usize].load(Ordering::Relaxed) == 0
        });
        if frontier.is_dense() && !enactor.densify_plain(n, frontier.len()) {
            frontier.to_sparse();
        }
        enactor.record_iteration(input_len, frontier.len(), t.elapsed_ms(), false);
    }
    let in_mis: Vec<bool> = state.into_iter().map(|a| a.into_inner() == 1).collect();
    (in_mis, enactor.finish_run())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder;
    use crate::graph::generators::{rmat, rmat::RmatParams, smallworld::smallworld, smallworld::SmallWorldParams};

    #[test]
    fn coloring_is_proper() {
        let g = smallworld(&SmallWorldParams { n: 512, k: 8, beta: 0.2, ..Default::default() });
        let (r, _) = color(&g, &Config::default());
        for v in 0..g.num_vertices as u32 {
            assert_ne!(r.colors[v as usize], UNCOLORED);
            for &u in g.neighbors(v) {
                assert_ne!(r.colors[v as usize], r.colors[u as usize], "edge {v}-{u}");
            }
        }
        assert!(r.num_colors >= 2);
    }

    #[test]
    fn bipartite_graph_gets_few_colors() {
        // even cycle is 2-colorable; greedy JP should stay small (<= 3)
        let edges: Vec<(u32, u32)> = (0..16u32).map(|v| (v, (v + 1) % 16)).collect();
        let g = builder::undirected_from_edges(16, &edges);
        let (r, _) = color(&g, &Config::default());
        assert!(r.num_colors <= 3, "{}", r.num_colors);
    }

    #[test]
    fn mis_is_independent_and_maximal() {
        let g = rmat(&RmatParams { scale: 9, edge_factor: 4, ..Default::default() });
        let (in_mis, _) = mis(&g, &Config::default());
        for v in 0..g.num_vertices as u32 {
            if in_mis[v as usize] {
                for &u in g.neighbors(v) {
                    assert!(!in_mis[u as usize] || u == v, "edge {v}-{u} inside MIS");
                }
            } else {
                // maximality: some neighbor (or itself via self loop) in MIS
                let covered = g.neighbors(v).iter().any(|&u| in_mis[u as usize]);
                assert!(covered, "vertex {v} not covered");
            }
        }
    }
}
