//! Breadth-first search (paper §6.1) — advance + filter per iteration,
//! with the full §5 optimization set:
//!
//! - push advance through any load-balancing strategy, or the fused
//!   LB_CULL advance+filter;
//! - idempotent mode (§5.2.1): atomic-free label writes, duplicates culled
//!   inexactly by the filter heuristics;
//! - direction-optimized traversal (§5.1.4, Algorithm 2): push/pull
//!   switching controlled by the do_a/do_b heuristic.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::config::Config;
use crate::enactor::{Direction, DirectionHeuristic, Enactor, RunResult};
use crate::frontier::lanes::{for_each_lane, LaneBits, LANES};
use crate::frontier::Frontier;
use crate::graph::{GraphRep, VertexId};
use crate::load_balance::StrategyKind;
use crate::operators::{advance, filter};
use crate::util::bitset::AtomicBitset;
use crate::util::timer::Timer;

pub const INFINITY_DEPTH: u32 = u32::MAX;

/// BFS problem state (paper: the Problem class holds labels + preds).
pub struct BfsProblem {
    pub labels: Vec<u32>,
    pub preds: Vec<i64>,
    pub src: VertexId,
}

#[derive(Clone, Debug)]
pub struct BfsStats {
    pub result: RunResult,
    pub pull_iterations: usize,
    pub push_iterations: usize,
}

/// Run BFS from `src` under `config`. Returns (problem, stats).
///
/// Generic over the graph representation: runs identically over raw
/// [`Csr`](crate::graph::Csr) and
/// [`CompressedCsr`](crate::graph::CompressedCsr) (decode-on-advance),
/// with bit-identical depth labels. Pull direction requires an in-edge
/// view (the CSC arrays on raw CSR, the v2 in-edge streams on compressed
/// graphs); representations without one traverse push-only even when
/// direction optimization is enabled.
pub fn bfs<G: GraphRep>(g: &G, src: VertexId, config: &Config) -> (BfsProblem, BfsStats) {
    let _span = crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::BFS, 1);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    // SoA problem data, shared across worker threads through atomics.
    let labels: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(INFINITY_DEPTH)).collect();
    let preds: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    labels[src as usize].store(0, Ordering::Relaxed);

    // Visited bitmask: doubles as the LB_CULL / idempotent-filter mask and
    // the pull-phase membership oracle.
    let visited = AtomicBitset::new(n);
    visited.set(src as usize);

    let mut heuristic =
        DirectionHeuristic::new(config.direction_optimized, config.do_a, config.do_b);
    let idempotent = config.idempotence;

    // Zero-alloc pipeline state: the enactor's ping-pong frontier queues
    // (taken for the run, returned at the end) plus a reusable raw-output
    // frontier for the sparse idempotent advance+filter pair. The pull
    // phase shares the input frontier's **dense bitmap** as its
    // membership oracle and sweeps the complement of `visited` in place —
    // no unvisited list, no second active bitmap anywhere.
    let mut bufs = std::mem::take(&mut enactor.frontiers);
    bufs.reset_single(src);
    let mut raw = Frontier::default();

    let mut depth: u32 = 0;
    let mut visited_count: usize = 1;
    let mut pull_iters = 0usize;
    let mut push_iters = 0usize;
    while !bufs.current().is_empty() && enactor.proceed() {
        let iter_timer = Timer::start();
        let prev_edges = enactor.counters.edges();
        let input_len = bufs.current().len();
        depth += 1;
        let dir = if g.has_in_edges() {
            heuristic.decide(n, g.num_edges(), input_len, n - visited_count)
        } else {
            Direction::Push
        };

        match dir {
            Direction::Pull => {
                pull_iters += 1;
                // Share the dense bitmap: the current frontier *is* the
                // pull membership oracle (converted in place on first
                // use; a pull-worthy frontier is dense already in auto
                // mode, so this is usually a no-op).
                bufs.current_mut().to_dense(n);
                let ctx = enactor.ctx();
                let d = depth;
                let (input, out) = bufs.split_mut();
                let in_bits = input.dense_bits().expect("pull input is dense");
                advance::advance_pull_into(
                    &ctx,
                    g,
                    &visited,
                    in_bits,
                    |v, parent| {
                        labels[v as usize].store(d, Ordering::Relaxed);
                        preds[v as usize].store(parent, Ordering::Relaxed);
                    },
                    out,
                );
                // Word-wise visited |= discovered: no per-vertex loop.
                out.dense_bits().expect("pull output is dense").union_into(&visited);
            }
            Direction::Push => {
                push_iters += 1;
                let strategy = enactor.strategy_for(g, input_len);
                let dense_out = enactor.densify_output(g, input_len);
                let ctx = enactor.ctx();
                let d = depth;
                if matches!(strategy, StrategyKind::LbCull) || !idempotent {
                    // Non-idempotent path: atomic claim on the visited mask
                    // decides the unique discoverer; fused cull produces a
                    // duplicate-free frontier in one pass (LB_CULL).
                    let fun = |s: VertexId, dst: VertexId, _e: usize| {
                        if visited.set(dst as usize) {
                            labels[dst as usize].store(d, Ordering::Relaxed);
                            preds[dst as usize].store(s, Ordering::Relaxed);
                            true
                        } else {
                            false
                        }
                    };
                    let (input, out) = bufs.split_mut();
                    if dense_out {
                        advance::advance_bitmap_into(&ctx, g, input, strategy, &fun, out);
                    } else {
                        advance::advance_into(
                            &ctx,
                            g,
                            input,
                            advance::AdvanceType::V2V,
                            strategy,
                            &fun,
                            out,
                        );
                    }
                } else if dense_out {
                    // Idempotent-discard path (§5.2.1): unconditional
                    // label writes + bitmap output. Stale duplicate
                    // discoveries are harmless and the fetch_or discards
                    // them for free, so the follow-up uniquify pass
                    // disappears entirely.
                    let fun = |s: VertexId, dst: VertexId, _e: usize| {
                        if labels[dst as usize].load(Ordering::Relaxed) == INFINITY_DEPTH {
                            labels[dst as usize].store(d, Ordering::Relaxed);
                            preds[dst as usize].store(s, Ordering::Relaxed);
                            true
                        } else {
                            false
                        }
                    };
                    let (input, out) = bufs.split_mut();
                    advance::advance_bitmap_into(&ctx, g, input, strategy, &fun, out);
                    // keep the visited mask (pull oracle + later sparse
                    // uniquify rounds) coherent, word-wise
                    out.dense_bits().expect("bitmap advance output").union_into(&visited);
                } else {
                    // Sparse idempotent path: emit dups, cull inexactly.
                    let fun = |s: VertexId, dst: VertexId, _e: usize| {
                        if labels[dst as usize].load(Ordering::Relaxed) == INFINITY_DEPTH {
                            labels[dst as usize].store(d, Ordering::Relaxed);
                            preds[dst as usize].store(s, Ordering::Relaxed);
                            true
                        } else {
                            false
                        }
                    };
                    advance::advance_into(
                        &ctx,
                        g,
                        bufs.current(),
                        advance::AdvanceType::V2V,
                        strategy,
                        &fun,
                        &mut raw,
                    );
                    filter::filter_uniquify_into(&ctx, &raw, &|_| true, &visited, bufs.next_mut());
                }
            }
        };

        let out_len = bufs.next().len();
        visited_count += out_len;
        // Ligra-style downswitch: a shrunken dense frontier converts back
        // to a queue before the next iteration's expansion.
        if bufs.next().is_dense() && !enactor.densify_output(g, out_len) {
            bufs.next_mut().to_sparse();
        }
        if dir == Direction::Push && !idempotent {
            // one visited-mask atomic per traversed edge (batched stat —
            // a per-edge atomic counter would double the atomic traffic)
            let e = enactor.counters.edges();
            enactor.counters.add_atomics(e.saturating_sub(prev_edges));
        }
        enactor.record_iteration(input_len, out_len, iter_timer.elapsed_ms(), dir == Direction::Pull);
        bufs.swap();
    }
    enactor.frontiers = bufs;

    let result = enactor.finish_run();
    let problem = BfsProblem {
        labels: labels.into_iter().map(|a| a.into_inner()).collect(),
        preds: preds
            .into_iter()
            .map(|a| {
                let v = a.into_inner();
                if v == u32::MAX {
                    -1
                } else {
                    v as i64
                }
            })
            .collect(),
        src,
    };
    let stats = BfsStats { result, pull_iterations: pull_iters, push_iterations: push_iters };
    (problem, stats)
}

/// Multi-source BFS problem state: lane-major depth labels, one column
/// per source. Batched mode trades predecessors for width (64 pred
/// arrays would octuple the memory traffic for a field point queries
/// never read) — run single-source [`bfs`] when a parent tree is needed.
pub struct MsBfsProblem {
    pub sources: Vec<VertexId>,
    /// `labels[lane][v]` = depth of `v` from `sources[lane]`
    /// ([`INFINITY_DEPTH`] if unreachable).
    pub labels: Vec<Vec<u32>>,
    /// Iteration at which each lane's frontier emptied (its settle point;
    /// the whole run stops when every lane has settled).
    pub settled_at: Vec<u32>,
}

/// Bit-parallel multi-source BFS: up to [`LANES`] sources advance in one
/// lane-word traversal ([`advance::advance_lanes_into`]) — each frontier
/// vertex's adjacency is decoded once for the whole batch, and a lane's
/// visited claim is a 1-bit `fetch_or` inside the shared word.
///
/// Per-lane results are **bit-identical** to [`bfs`] from the same
/// source: both engines are level-synchronous, and a vertex's depth is
/// the (deterministic) first BSP level that reaches it, independent of
/// which engine or worker claims it. Holds over raw and compressed
/// representations alike.
pub fn multi_source_bfs<G: GraphRep>(
    g: &G,
    sources: &[VertexId],
    config: &Config,
) -> (MsBfsProblem, RunResult) {
    let k = sources.len();
    assert!(
        (1..=LANES).contains(&k),
        "multi_source_bfs takes 1..={LANES} sources, got {k}"
    );
    let _span =
        crate::obs::span(crate::obs::EventKind::PrimitiveRun, crate::obs::tags::BFS, k as u64);
    let n = g.num_vertices();
    let mut enactor = Enactor::new(config.clone());
    enactor.begin_run();

    // Lane-major label columns: scatter-back touches one lane's column.
    let labels: Vec<Vec<AtomicU32>> =
        (0..k).map(|_| (0..n).map(|_| AtomicU32::new(INFINITY_DEPTH)).collect()).collect();
    let visited = LaneBits::new(n);
    let mut cur = LaneBits::new(n);
    let mut next = LaneBits::new(n);
    for (lane, &src) in sources.iter().enumerate() {
        visited.merge(src as usize, 1 << lane);
        cur.merge(src as usize, 1 << lane);
        labels[lane][src as usize].store(0, Ordering::Relaxed);
    }
    cur.seal();

    let mut settled_at = vec![0u32; k];
    let mut live: u64 = if k == LANES { u64::MAX } else { (1u64 << k) - 1 };
    let mut depth: u32 = 0;
    while !cur.is_empty() && enactor.proceed() {
        let iter_timer = Timer::start();
        let input_len = cur.active_vertices();
        depth += 1;
        let strategy = enactor.strategy_for(g, input_len);
        let ctx = enactor.ctx();
        let d = depth;
        let labels = &labels;
        let visited = &visited;
        advance::advance_lanes_into(
            &ctx,
            g,
            &cur,
            strategy,
            &|_s: VertexId, dst: VertexId, _e: usize, mask: u64| {
                // Per-lane claim: fetch_or returns the lanes that newly
                // visited dst — exactly those store their depth (unique
                // claimer per lane, like the visited.set path in `bfs`).
                let newly = visited.merge(dst as usize, mask);
                if newly != 0 {
                    for_each_lane(newly, |lane| {
                        labels[lane][dst as usize].store(d, Ordering::Relaxed);
                    });
                }
                newly
            },
            &mut next,
        );
        // Per-lane settle detection: a lane missing from the sealed
        // union has an empty frontier and is done.
        let gone = live & !next.lane_union();
        if gone != 0 {
            for_each_lane(gone, |lane| settled_at[lane] = depth);
            live &= next.lane_union();
        }
        enactor.record_iteration(input_len, next.active_vertices(), iter_timer.elapsed_ms(), false);
        std::mem::swap(&mut cur, &mut next);
    }

    let mut result = enactor.finish_run();
    result.lanes = k;
    let problem = MsBfsProblem {
        sources: sources.to_vec(),
        labels: labels
            .into_iter()
            .map(|col| col.into_iter().map(|a| a.into_inner()).collect())
            .collect(),
        settled_at,
    };
    (problem, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{rmat, rmat::RmatParams};
    use crate::graph::{builder, Csr};

    fn path_graph(n: usize) -> Csr {
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|v| (v, v + 1)).collect();
        builder::undirected_from_edges(n, &edges)
    }

    #[test]
    fn path_depths() {
        let g = path_graph(10);
        let (p, s) = bfs(&g, 0, &Config::default());
        for v in 0..10 {
            assert_eq!(p.labels[v], v as u32);
        }
        assert_eq!(s.result.num_iterations(), 9 + 1); // 9 levels + empty tail... (last iteration produces empty)
    }

    #[test]
    fn unreachable_stays_infinity() {
        let g = builder::from_edges(4, &[(0, 1)]);
        let (p, _) = bfs(&g, 0, &Config::default());
        assert_eq!(p.labels[2], INFINITY_DEPTH);
        assert_eq!(p.preds[2], -1);
    }

    #[test]
    fn preds_form_valid_tree() {
        let g = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() });
        let (p, _) = bfs(&g, 0, &Config::default());
        for v in 0..g.num_vertices {
            if p.labels[v] != INFINITY_DEPTH && v != 0 {
                let pred = p.preds[v];
                assert!(pred >= 0);
                assert_eq!(p.labels[pred as usize] + 1, p.labels[v], "v={v}");
                assert!(g.neighbors(pred as u32).contains(&(v as u32)));
            }
        }
    }

    #[test]
    fn idempotent_matches_exact() {
        let g = rmat(&RmatParams { scale: 10, edge_factor: 16, ..Default::default() });
        let (exact, _) = bfs(&g, 3, &Config::default());
        let mut cfg = Config::default();
        cfg.idempotence = true;
        let (idem, _) = bfs(&g, 3, &cfg);
        assert_eq!(exact.labels, idem.labels);
    }

    #[test]
    fn direction_optimized_matches_push_only() {
        let g = rmat(&RmatParams { scale: 10, edge_factor: 16, ..Default::default() });
        let (push, _) = bfs(&g, 7, &Config::default());
        let mut cfg = Config::default();
        cfg.direction_optimized = true;
        let (dopt, stats) = bfs(&g, 7, &cfg);
        assert_eq!(push.labels, dopt.labels);
        assert!(stats.pull_iterations > 0, "scale-free BFS should enter pull phase");
    }

    #[test]
    fn compressed_representation_matches_csr() {
        use crate::graph::{Codec, CompressedCsr};
        let g = rmat(&RmatParams { scale: 10, edge_factor: 16, ..Default::default() });
        let (want, _) = bfs(&g, 5, &Config::default());
        for codec in [Codec::Varint, Codec::Zeta(2)] {
            let cg = CompressedCsr::from_csr(&g, codec);
            let (got, _) = bfs(&cg, 5, &Config::default());
            assert_eq!(want.labels, got.labels, "{codec}");
        }
    }

    #[test]
    fn direction_optimized_over_compressed_matches_csr() {
        use crate::graph::{Codec, CompressedCsr};
        let g = rmat(&RmatParams { scale: 10, edge_factor: 16, ..Default::default() });
        let mut cfg = Config::default();
        cfg.direction_optimized = true;
        let (want, want_stats) = bfs(&g, 7, &cfg);
        let cg = CompressedCsr::from_csr_with_in_edges(&g, Codec::Varint);
        let (got, got_stats) = bfs(&cg, 7, &cfg);
        assert_eq!(want.labels, got.labels);
        assert!(got_stats.pull_iterations > 0, "compressed DO-BFS must enter the pull phase");
        // Frontier sizes match per level (exact dedup both ways), so the
        // direction heuristic takes the same push/pull schedule.
        assert_eq!(want_stats.pull_iterations, got_stats.pull_iterations);
    }

    #[test]
    fn multi_source_matches_sequential_bit_exact() {
        let g = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() });
        let sources: Vec<u32> = (0..64u32).map(|i| (i * 7) % g.num_vertices as u32).collect();
        let cfg = Config::default();
        let (ms, r) = multi_source_bfs(&g, &sources, &cfg);
        assert_eq!(r.lanes, 64);
        for (lane, &src) in sources.iter().enumerate() {
            let (p, _) = bfs(&g, src, &cfg);
            assert_eq!(ms.labels[lane], p.labels, "lane {lane} src {src}");
        }
    }

    #[test]
    fn lanes_settle_independently() {
        // 0->1->2 plus isolated 3: a source at 2 settles before one at 0.
        let g = builder::from_edges(4, &[(0, 1), (1, 2)]);
        let (ms, _) = multi_source_bfs(&g, &[0, 2], &Config::default());
        assert_eq!(ms.labels[0], vec![0, 1, 2, INFINITY_DEPTH]);
        assert_eq!(ms.labels[1], vec![INFINITY_DEPTH, INFINITY_DEPTH, 0, INFINITY_DEPTH]);
        assert!(ms.settled_at[1] <= ms.settled_at[0]);
    }

    #[test]
    fn duplicate_sources_share_a_word() {
        let g = path_graph(6);
        let (ms, _) = multi_source_bfs(&g, &[3, 3, 0], &Config::default());
        assert_eq!(ms.labels[0], ms.labels[1], "duplicate lanes agree");
        assert_eq!(ms.labels[2][5], 5);
    }

    #[test]
    fn all_strategies_agree() {
        let g = rmat(&RmatParams { scale: 9, edge_factor: 8, ..Default::default() });
        let (want, _) = bfs(&g, 0, &Config::default());
        for strat in [
            StrategyKind::ThreadExpand,
            StrategyKind::Twc,
            StrategyKind::Lb,
            StrategyKind::LbLight,
            StrategyKind::LbCull,
        ] {
            let mut cfg = Config::default();
            cfg.strategy = Some(strat);
            let (got, _) = bfs(&g, 0, &cfg);
            assert_eq!(want.labels, got.labels, "{strat}");
        }
    }
}
