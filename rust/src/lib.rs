//! # gunrock-rs — Gunrock: GPU Graph Analytics, reproduced
//!
//! A CPU-simulated, three-layer (Rust + JAX + Pallas) reproduction of
//! *Gunrock: GPU Graph Analytics* (Wang et al., ACM TOPC 2017).
//!
//! The paper's data-centric, frontier-oriented programming model lives in
//! this crate: frontiers ([`frontier`]), the four graph operators
//! ([`operators`]), GPU workload-mapping strategies executed on a
//! virtual-warp model ([`load_balance`], [`gpu_sim`]), the enactor/problem
//! architecture ([`enactor`]), and the paper's graph primitives
//! ([`primitives`]) with their CPU comparators ([`baselines`]).
//!
//! Dense fixed-shape iteration steps (PageRank, pull-BFS) can also execute
//! through AOT-compiled XLA artifacts authored in JAX/Pallas at build time
//! ([`runtime`]); Python is never on the request path.
//!
//! See DESIGN.md for the paper -> module map and EXPERIMENTS.md for
//! paper-vs-measured results on every table and figure.

pub mod baselines;
pub mod config;
pub mod enactor;
pub mod frontier;
pub mod gpu_sim;
pub mod graph;
pub mod harness;
pub mod load_balance;
pub mod multi_gpu;
pub mod operators;
pub mod primitives;
pub mod runtime;
pub mod util;
