//! # gunrock-rs — Gunrock: GPU Graph Analytics, reproduced
//!
//! A CPU-simulated, three-layer (Rust + JAX + Pallas) reproduction of
//! *Gunrock: GPU Graph Analytics* (Wang et al., ACM TOPC 2017).
//!
//! The paper's data-centric, frontier-oriented programming model lives in
//! this crate: frontiers ([`frontier`]), the four graph operators
//! ([`operators`]), GPU workload-mapping strategies executed on a
//! virtual-warp model ([`load_balance`], [`gpu_sim`]), the enactor/problem
//! architecture ([`enactor`]), and the paper's graph primitives
//! ([`primitives`]) with their CPU comparators ([`baselines`]).
//!
//! Every primitive is invoked through one surface — the
//! [`primitives::api`] request/response layer — and the concurrent query
//! service ([`service`]) batches point queries through the bit-parallel
//! 64-lane multi-source engines ([`frontier::lanes`]).
//!
//! See DESIGN.md for the paper -> module map and EXPERIMENTS.md for
//! paper-vs-measured results on every table and figure.

// Lint policy (CI runs `clippy -- -D warnings` as a required job).
// Deliberate idioms the codebase keeps, rather than per-site attributes:
// - field_reassign_with_default: `let mut cfg = Config::default();
//   cfg.x = ...;` is the config-override idiom used throughout benches,
//   tests, and the CLI — clearer than a builder for a plain struct.
// - too_many_arguments: operator entry points mirror the paper's kernel
//   signatures (ctx, graph, frontier, functor, strategy, out, ...).
// - needless_range_loop: index loops over parallel SoA arrays keep the
//   shared index visible; iterator zips of 3+ arrays read worse.
#![allow(
    clippy::field_reassign_with_default,
    clippy::too_many_arguments,
    clippy::needless_range_loop
)]

pub mod baselines;
pub mod config;
pub mod enactor;
pub mod frontier;
pub mod gpu_sim;
pub mod graph;
pub mod harness;
pub mod load_balance;
pub mod multi_gpu;
// Observability shares the serving stack's no-unwrap discipline: the
// flight recorder runs precisely when something else already failed.
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod obs;
pub mod operators;
pub mod primitives;
pub mod runtime;
// The serving stack must never die on an unwrap: every failure path is a
// typed QueryError a client can observe. Enforced at the module root
// (tests re-allow locally).
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod service;
pub mod util;
