//! Unified metrics registry: named counters, gauges, and fixed-bucket
//! latency histograms behind lock-free handles.
//!
//! Registration (name lookup) takes a mutex; it happens once per handle
//! and the handles themselves are plain atomics, so the record path never
//! blocks. Histograms keep a small reservoir of recent samples so the
//! snapshot can report p50/p95/p99 through [`crate::util::stats::percentile`]
//! alongside the cumulative buckets; the reservoir uses `try_lock` and
//! drops the sample on contention rather than ever stalling a recorder.
//!
//! This registry is the one export surface for numbers that used to be
//! siloed per layer: every primitive's `RunResult` feeds it (see
//! [`super::record_run`], which absorbs the `gpu_sim::WarpCounters`-derived
//! fields), and the query service's `StatsSnapshot` is folded in at
//! export time by the `metrics` protocol command.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::stats;

/// Poison-immune lock: observability must keep working after a panic
/// elsewhere (that is exactly when the flight recorder matters).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Upper bucket bounds in milliseconds; one extra implicit +inf bucket.
pub const BUCKET_BOUNDS_MS: [f64; 14] =
    [0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0];

/// Recent-sample reservoir size per histogram (for percentile reporting).
const RECENT_CAP: usize = 512;

/// Monotonic counter handle. Cloning shares the underlying cell.
///
/// Overflow semantics: increments use atomic `fetch_add`, which wraps
/// modulo 2^64 by definition — never a panic, in debug or release
/// builds. At one increment per nanosecond a counter takes ~584 years
/// to wrap, so wrap-around is a documented non-event rather than a
/// guarded path; long-soak counters elsewhere (`service::StatsSnapshot`,
/// the governor ledger) saturate instead because they are read back for
/// arithmetic.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1. Wraps modulo 2^64 at `u64::MAX`; never panics.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`. Wraps modulo 2^64 on overflow; never panics.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in an atomic).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistInner {
    /// One count per `BUCKET_BOUNDS_MS` entry plus a final +inf bucket.
    counts: [AtomicU64; BUCKET_BOUNDS_MS.len() + 1],
    count: AtomicU64,
    sum_us: AtomicU64,
    recent: Mutex<Recent>,
}

struct Recent {
    vals: Vec<f64>,
    next: usize,
}

/// Fixed-bucket latency histogram handle (milliseconds).
#[derive(Clone)]
pub struct Histogram(Arc<HistInner>);

impl Histogram {
    fn new() -> Self {
        Histogram(Arc::new(HistInner {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            recent: Mutex::new(Recent { vals: Vec::new(), next: 0 }),
        }))
    }

    pub fn observe_ms(&self, v: f64) {
        let idx = BUCKET_BOUNDS_MS
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(BUCKET_BOUNDS_MS.len());
        self.0.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum_us.fetch_add((v.max(0.0) * 1e3) as u64, Ordering::Relaxed);
        // Reservoir is best-effort: skip under contention, never block.
        if let Ok(mut r) = self.0.recent.try_lock() {
            if r.vals.len() < RECENT_CAP {
                r.vals.push(v);
            } else {
                let i = r.next;
                r.vals[i] = v;
                r.next = (i + 1) % RECENT_CAP;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum_ms(&self) -> f64 {
        self.0.sum_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Percentile over the recent-sample reservoir (nearest-rank via
    /// `util::stats`, which is NaN-tolerant).
    pub fn percentile(&self, p: f64) -> f64 {
        let vals = lock(&self.0.recent).vals.clone();
        stats::percentile(&vals, p)
    }

    fn value_snapshot(&self) -> MetricValue {
        let mut buckets = Vec::with_capacity(BUCKET_BOUNDS_MS.len() + 1);
        for (i, c) in self.0.counts.iter().enumerate() {
            let bound = BUCKET_BOUNDS_MS.get(i).copied().unwrap_or(f64::INFINITY);
            buckets.push((bound, c.load(Ordering::Relaxed)));
        }
        MetricValue::Histogram {
            count: self.count(),
            sum_ms: self.sum_ms(),
            buckets,
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
        }
    }
}

/// One exported metric: registered name (may embed `{label="..."}`
/// pairs) plus its current value.
#[derive(Clone, Debug)]
pub struct MetricSnapshot {
    pub name: String,
    pub value: MetricValue,
}

#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram {
        count: u64,
        sum_ms: f64,
        /// Per-bucket (non-cumulative) counts keyed by upper bound;
        /// the final entry's bound is `f64::INFINITY`.
        buckets: Vec<(f64, u64)>,
        p50: f64,
        p95: f64,
        p99: f64,
    },
}

/// Find-or-create registry of named metrics. One process-wide instance
/// (see [`metrics`]); standalone instances exist for tests.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<Vec<(String, Counter)>>,
    gauges: Mutex<Vec<(String, Gauge)>>,
    hists: Mutex<Vec<(String, Histogram)>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Find-or-create a counter. Callers should cache the handle; the
    /// lookup takes the registration lock.
    pub fn counter(&self, name: &str) -> Counter {
        let mut list = lock(&self.counters);
        if let Some((_, c)) = list.iter().find(|(n, _)| n == name) {
            return c.clone();
        }
        let c = Counter(Arc::new(AtomicU64::new(0)));
        list.push((name.to_string(), c.clone()));
        c
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut list = lock(&self.gauges);
        if let Some((_, g)) = list.iter().find(|(n, _)| n == name) {
            return g.clone();
        }
        let g = Gauge(Arc::new(AtomicU64::new(0f64.to_bits())));
        list.push((name.to_string(), g.clone()));
        g
    }

    pub fn histogram_ms(&self, name: &str) -> Histogram {
        let mut list = lock(&self.hists);
        if let Some((_, h)) = list.iter().find(|(n, _)| n == name) {
            return h.clone();
        }
        let h = Histogram::new();
        list.push((name.to_string(), h.clone()));
        h
    }

    /// Point-in-time copy of every registered metric, in registration
    /// order (counters, then gauges, then histograms).
    pub fn snapshot(&self) -> Vec<MetricSnapshot> {
        let mut out = Vec::new();
        for (n, c) in lock(&self.counters).iter() {
            out.push(MetricSnapshot { name: n.clone(), value: MetricValue::Counter(c.get()) });
        }
        for (n, g) in lock(&self.gauges).iter() {
            out.push(MetricSnapshot { name: n.clone(), value: MetricValue::Gauge(g.get()) });
        }
        for (n, h) in lock(&self.hists).iter() {
            out.push(MetricSnapshot { name: n.clone(), value: h.value_snapshot() });
        }
        out
    }
}

/// The process-wide registry.
pub fn metrics() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]
    use super::*;

    #[test]
    fn counter_find_or_create_shares_cell() {
        let r = Registry::new();
        let a = r.counter("runs_total{kind=\"bfs\"}");
        let b = r.counter("runs_total{kind=\"bfs\"}");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 1);
        match &snap[0].value {
            MetricValue::Counter(v) => assert_eq!(*v, 3),
            other => panic!("expected counter, got {other:?}"),
        }
    }

    #[test]
    fn gauge_roundtrips_f64() {
        let r = Registry::new();
        let g = r.gauge("warp_efficiency");
        g.set(0.75);
        assert_eq!(g.get(), 0.75);
    }

    #[test]
    fn histogram_buckets_and_percentiles() {
        let r = Registry::new();
        let h = r.histogram_ms("latency_ms");
        for v in [0.05, 0.2, 0.2, 3.0, 40.0, 9000.0] {
            h.observe_ms(v);
        }
        assert_eq!(h.count(), 6);
        assert!((h.sum_ms() - 9043.45).abs() < 1.0);
        match r.snapshot().pop().unwrap().value {
            MetricValue::Histogram { count, buckets, p50, .. } => {
                assert_eq!(count, 6);
                // 0.05 -> le=0.1; 0.2 x2 -> le=0.25; 3.0 -> le=5; 40 -> le=50;
                // 9000 -> +inf.
                let get = |bound: f64| {
                    buckets.iter().find(|(b, _)| *b == bound).map(|(_, c)| *c).unwrap()
                };
                assert_eq!(get(0.1), 1);
                assert_eq!(get(0.25), 2);
                assert_eq!(get(5.0), 1);
                assert_eq!(get(50.0), 1);
                assert_eq!(get(f64::INFINITY), 1);
                assert!(p50 > 0.0);
            }
            other => panic!("expected histogram, got {other:?}"),
        }
        // Percentiles come from the recent reservoir via util::stats.
        assert_eq!(h.percentile(0.0), 0.05);
        assert_eq!(h.percentile(100.0), 9000.0);
    }

    #[test]
    fn counter_overflow_wraps_and_never_panics() {
        // Regression: atomic fetch_add wraps modulo 2^64 even in debug
        // builds (no overflow panic), so a counter pinned at the top of
        // the range cannot crash a long soak.
        let r = Registry::new();
        let c = r.counter("wraps_total");
        c.add(u64::MAX);
        assert_eq!(c.get(), u64::MAX);
        c.inc();
        assert_eq!(c.get(), 0, "wraps modulo 2^64 by definition");
        c.add(7);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn reservoir_wraps_at_cap() {
        let r = Registry::new();
        let h = r.histogram_ms("wrap");
        for i in 0..(RECENT_CAP * 2) {
            h.observe_ms(i as f64);
        }
        // Oldest half has been overwritten: min recent sample >= cap.
        assert!(h.percentile(0.0) >= RECENT_CAP as f64);
        assert_eq!(h.count(), (RECENT_CAP * 2) as u64);
    }
}
