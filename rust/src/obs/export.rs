//! Exporters: Chrome `trace_event` JSON and Prometheus-style text.
//!
//! The Chrome format is the JSON array flavor documented by the Trace
//! Event Profiling Tool: complete spans are `ph:"X"` with `ts`/`dur` in
//! microseconds, instants are `ph:"i"` with thread scope. The output of
//! [`write_chrome_trace`] loads directly in `chrome://tracing` or
//! <https://ui.perfetto.dev>; each per-thread ring renders as one track.
//!
//! The Prometheus exporter is a plain-text snapshot (`# TYPE` headers,
//! `name{labels} value` samples) built from a registry snapshot plus any
//! caller-supplied extra counters — that is how the query service's
//! `StatsSnapshot` fields are folded into the same exposition as the
//! registry metrics (the serve protocol's `metrics` command).

use std::fmt::Write as _;

use super::registry::{MetricSnapshot, MetricValue};
use super::{all_events_sorted, prim_name, strategy_name, EventKind};

/// Render every retained ring event as a Chrome trace-event JSON string.
pub fn chrome_trace_json() -> String {
    let events = all_events_sorted();
    let mut out = String::with_capacity(128 + 160 * events.len());
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut seen_tids: Vec<u32> = Vec::new();
    for e in &events {
        if !seen_tids.contains(&e.tid) {
            seen_tids.push(e.tid);
        }
        if !first {
            out.push(',');
        }
        first = false;
        let (an, bn) = e.kind.arg_names();
        let (av, bv) = (arg_value(e.kind, true, e.a), arg_value(e.kind, false, e.b));
        out.push('\n');
        if e.kind.is_instant() && e.dur_us == 0 {
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"gunrock\",\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\"tid\":{},\"ts\":{},\"args\":{{\"{}\":{},\"{}\":{},\"depth\":{}}}}}",
                e.kind.name(), e.tid, e.ts_us, an, av, bn, bv, e.depth
            );
        } else {
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"gunrock\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{\"{}\":{},\"{}\":{},\"depth\":{}}}}}",
                e.kind.name(), e.tid, e.ts_us, e.dur_us, an, av, bn, bv, e.depth
            );
        }
    }
    // Thread-name metadata so tracks are labeled in the viewer.
    for tid in seen_tids {
        let _ = write!(
            out,
            ",\n{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\"args\":{{\"name\":\"ring-{tid}\"}}}}"
        );
    }
    out.push_str("\n]}");
    out
}

/// Render tag-typed payloads as their symbolic name (JSON string),
/// everything else as a bare number.
fn arg_value(kind: EventKind, is_a: bool, v: u64) -> String {
    let named: Option<&'static str> = match (kind, is_a) {
        (EventKind::OperatorDispatch | EventKind::LbStrategy, true) => Some(strategy_name(v)),
        (EventKind::PrimitiveRun, true) => Some(prim_name(v)),
        (
            EventKind::QueueAdmit
            | EventKind::QueueCoalesce
            | EventKind::QueueReject
            | EventKind::QueueShed
            | EventKind::CacheHit
            | EventKind::BatcherDrain,
            true,
        ) => Some(prim_name(v)),
        (EventKind::BudgetTrip, false) => Some(super::interrupt_name(v)),
        _ => None,
    };
    match named {
        Some(n) => format!("\"{n}\""),
        None => v.to_string(),
    }
}

/// Write the Chrome trace to `path`.
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json())
}

/// Prometheus-style text exposition: caller-supplied counters first
/// (e.g. the service `StatsSnapshot` / queue introspection), then the
/// registry snapshot. All sample names get a `gunrock_` prefix.
pub fn prometheus_text(extra_counters: &[(&str, u64)], registry: &[MetricSnapshot]) -> String {
    let mut out = String::new();
    for (name, v) in extra_counters {
        type_line(&mut out, name, "counter");
        let _ = writeln!(out, "gunrock_{name} {v}");
    }
    for m in registry {
        match &m.value {
            MetricValue::Counter(v) => {
                type_line(&mut out, &m.name, "counter");
                let _ = writeln!(out, "gunrock_{} {v}", m.name);
            }
            MetricValue::Gauge(v) => {
                type_line(&mut out, &m.name, "gauge");
                let _ = writeln!(out, "gunrock_{} {v}", m.name);
            }
            MetricValue::Histogram { count, sum_ms, buckets, p50, p95, p99 } => {
                type_line(&mut out, &m.name, "histogram");
                let (base, labels) = split_labels(&m.name);
                let mut cumulative = 0u64;
                for (bound, c) in buckets {
                    cumulative += c;
                    let le = if bound.is_infinite() {
                        "+Inf".to_string()
                    } else {
                        format!("{bound}")
                    };
                    let _ = writeln!(
                        out,
                        "gunrock_{base}_bucket{{{labels}le=\"{le}\"}} {cumulative}"
                    );
                }
                let _ = writeln!(out, "gunrock_{base}_sum{{{labels_t}}} {sum_ms}", labels_t = labels.trim_end_matches(','));
                let _ = writeln!(out, "gunrock_{base}_count{{{labels_t}}} {count}", labels_t = labels.trim_end_matches(','));
                for (q, v) in [("0.5", p50), ("0.95", p95), ("0.99", p99)] {
                    let _ = writeln!(
                        out,
                        "gunrock_{base}{{{labels}quantile=\"{q}\"}} {v}"
                    );
                }
            }
        }
    }
    out
}

/// Emit a `# TYPE` header once per base metric name.
fn type_line(out: &mut String, name: &str, ty: &str) {
    let (base, _) = split_labels(name);
    let header = format!("# TYPE gunrock_{base} {ty}\n");
    if !out.contains(&header) {
        out.push_str(&header);
    }
}

/// Split `"run_ms{kind=\"bfs\"}"` into `("run_ms", "kind=\"bfs\",")` —
/// the label part keeps a trailing comma (or is empty) so callers can
/// append their own labels.
fn split_labels(name: &str) -> (&str, String) {
    match name.split_once('{') {
        Some((base, rest)) => {
            let inner = rest.trim_end_matches('}');
            if inner.is_empty() {
                (base, String::new())
            } else {
                (base, format!("{inner},"))
            }
        }
        None => (name, String::new()),
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]
    use super::super::{event, set_enabled, span, test_guard, EventKind, Registry};
    use super::*;

    #[test]
    fn chrome_trace_is_wellformed_and_contains_spans() {
        let _g = test_guard::hold();
        set_enabled(true);
        {
            let _s = span(EventKind::OperatorDispatch, 1, 500);
            event(EventKind::LbStrategy, 1, 500);
        }
        set_enabled(false);
        let json = chrome_trace_json();
        assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(json.ends_with("\n]}"));
        assert!(json.contains("\"name\":\"operator_dispatch\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"strategy\":\"twc\""), "tagged args render symbolically");
        assert!(json.contains("\"name\":\"thread_name\""));
        // Balanced braces/brackets as a cheap well-formedness check.
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn prometheus_text_has_types_labels_and_extras() {
        let r = Registry::new();
        r.counter("runs_total{kind=\"bfs\"}").add(5);
        r.gauge("warp_efficiency_last").set(0.5);
        let h = r.histogram_ms("run_ms{kind=\"bfs\"}");
        h.observe_ms(0.2);
        h.observe_ms(30.0);
        let text = prometheus_text(&[("service_served_total", 9)], &r.snapshot());
        assert!(text.contains("# TYPE gunrock_service_served_total counter"));
        assert!(text.contains("gunrock_service_served_total 9"));
        assert!(text.contains("gunrock_runs_total{kind=\"bfs\"} 5"));
        assert!(text.contains("# TYPE gunrock_run_ms histogram"));
        assert!(text.contains("gunrock_run_ms_bucket{kind=\"bfs\",le=\"0.25\"} 1"));
        assert!(text.contains("gunrock_run_ms_bucket{kind=\"bfs\",le=\"+Inf\"} 2"));
        assert!(text.contains("gunrock_run_ms_count{kind=\"bfs\"} 2"));
        assert!(text.contains("quantile=\"0.5\""));
        assert!(text.contains("gunrock_warp_efficiency_last 0.5"));
    }

    #[test]
    fn split_labels_handles_bare_names() {
        assert_eq!(split_labels("foo"), ("foo", String::new()));
        assert_eq!(split_labels("foo{a=\"b\"}"), ("foo", "a=\"b\",".to_string()));
    }
}
