//! Flight recorder: a post-mortem for typed failures.
//!
//! When something trips — a run budget (deadline / cancellation /
//! iteration cap), a batcher panic, or load shedding — the last N ring
//! events are formatted into a compact text dump, written to stderr, and
//! retained in memory so callers (and tests) can fetch the most recent
//! one with [`last_flight_dump`]. The error value a client sees (PR 7's
//! typed `QueryError`s) therefore comes with the trace that led up to
//! it, without anyone having asked for a trace in advance.
//!
//! Dumps are no-ops while tracing is disabled. Shed dumps are
//! rate-limited (sheds arrive in bursts under overload; one dump per
//! burst is the useful signal) — budget trips and batcher panics are
//! never rate-limited, they are one-per-failure by construction.
//!
//! When `FLIGHT_DUMP_DIR` is set in the environment, every dump is also
//! persisted there as `flight-<pid>-<seq>.txt` — CI points it at the
//! workspace so failing jobs upload the dumps as artifacts. Persistence
//! is strictly best-effort: a failure path must never fail harder
//! because its post-mortem could not be written.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::{enabled, lock, now_us};

/// How many trailing events a dump includes.
pub const FLIGHT_TAIL: usize = 96;

/// Minimum spacing between shed-triggered dumps.
const SHED_DUMP_MIN_INTERVAL_US: u64 = 500_000;

static LAST_DUMP: Mutex<Option<String>> = Mutex::new(None);
/// `u64::MAX` = "never dumped for shed yet".
static LAST_SHED_DUMP_US: AtomicU64 = AtomicU64::new(u64::MAX);
/// Monotone suffix for persisted dump filenames within this process.
static DUMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Best-effort file persistence for a dump: no-op unless the
/// `FLIGHT_DUMP_DIR` environment variable names a directory. Every
/// failure is swallowed — a dump is diagnostics, never a new fault.
fn persist_dump(text: &str) {
    let Ok(dir) = std::env::var("FLIGHT_DUMP_DIR") else { return };
    if dir.is_empty() {
        return;
    }
    let n = DUMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/flight-{}-{n}.txt", std::process::id());
    let _ = std::fs::write(path, text);
}

/// Dump the last [`FLIGHT_TAIL`] events across all rings. Returns the
/// dump text (also written to stderr and retained for
/// [`last_flight_dump`]), or `None` when tracing is disabled.
pub fn flight_dump(reason: &str) -> Option<String> {
    if !enabled() {
        return None;
    }
    let events = super::all_events_sorted();
    let tail_start = events.len().saturating_sub(FLIGHT_TAIL);
    let tail = &events[tail_start..];
    let mut s = String::with_capacity(64 + 80 * tail.len());
    let _ = writeln!(
        s,
        "flight-recorder: {reason} ({} of {} retained events, newest last)",
        tail.len(),
        events.len()
    );
    for e in tail {
        let (an, bn) = e.kind.arg_names();
        let _ = writeln!(
            s,
            "  t={:>10}us dur={:>8}us tid={:<3} depth={} {:<18} {}={} {}={}",
            e.ts_us,
            e.dur_us,
            e.tid,
            e.depth,
            e.kind.name(),
            an,
            e.a,
            bn,
            e.b
        );
    }
    *lock(&LAST_DUMP) = Some(s.clone());
    eprint!("{s}");
    persist_dump(&s);
    Some(s)
}

/// [`flight_dump`] for load shedding: identical, but bursts within
/// 500 ms collapse into one dump.
pub fn flight_dump_shed(reason: &str) -> Option<String> {
    if !enabled() {
        return None;
    }
    let now = now_us();
    let last = LAST_SHED_DUMP_US.load(Ordering::Relaxed);
    if last != u64::MAX && now.saturating_sub(last) < SHED_DUMP_MIN_INTERVAL_US {
        return None;
    }
    LAST_SHED_DUMP_US.store(now, Ordering::Relaxed);
    flight_dump(reason)
}

/// The most recent dump, if any.
pub fn last_flight_dump() -> Option<String> {
    lock(&LAST_DUMP).clone()
}

/// Forget the retained dump (test isolation).
pub fn clear_last_dump() {
    *lock(&LAST_DUMP) = None;
    LAST_SHED_DUMP_US.store(u64::MAX, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]
    use super::super::{event, set_enabled, test_guard, EventKind};
    use super::*;

    #[test]
    fn dump_contains_recent_events_and_reason() {
        let _g = test_guard::hold();
        set_enabled(true);
        clear_last_dump();
        event(EventKind::BudgetTrip, 41, 0);
        let dump = flight_dump("unit-test trip").expect("armed dump");
        set_enabled(false);
        assert!(dump.contains("unit-test trip"));
        assert!(dump.contains("budget_trip"));
        assert!(dump.contains("iteration=41"));
        assert_eq!(last_flight_dump().as_deref(), Some(dump.as_str()));
    }

    #[test]
    fn disabled_dump_is_none() {
        let _g = test_guard::hold();
        set_enabled(false);
        clear_last_dump();
        assert!(flight_dump("nope").is_none());
        assert!(last_flight_dump().is_none());
    }

    #[test]
    fn dump_persists_to_flight_dump_dir() {
        let _g = test_guard::hold();
        let dir = std::env::temp_dir()
            .join(format!("gunrock_flight_{}", std::process::id()));
        std::env::set_var("FLIGHT_DUMP_DIR", &dir);
        set_enabled(true);
        clear_last_dump();
        event(EventKind::BudgetTrip, 7, 0);
        let dump = flight_dump("persisted trip").expect("armed dump");
        set_enabled(false);
        std::env::remove_var("FLIGHT_DUMP_DIR");
        let mut found = None;
        for entry in std::fs::read_dir(&dir).expect("dump dir exists") {
            let p = entry.unwrap().path();
            if p.file_name().unwrap().to_str().unwrap().starts_with("flight-") {
                found = Some(std::fs::read_to_string(&p).unwrap());
            }
        }
        assert_eq!(found.as_deref(), Some(dump.as_str()), "dump file matches stderr dump");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shed_dumps_are_rate_limited() {
        let _g = test_guard::hold();
        set_enabled(true);
        clear_last_dump();
        event(EventKind::QueueShed, 1, 2);
        assert!(flight_dump_shed("first").is_some());
        assert!(flight_dump_shed("burst").is_none(), "second dump within 500ms suppressed");
        set_enabled(false);
        clear_last_dump();
    }
}
