//! Lock-free, fixed-capacity event ring — the tracing analog of the
//! pool's scratch recycler: a bounded, process-lifetime buffer that a hot
//! path writes into without ever blocking or allocating.
//!
//! Each ring has exactly **one writer** (the owning thread; see the
//! thread-local registration in the parent module) and any number of
//! concurrent readers. The writer publishes drop-oldest: slot `i % cap`
//! is overwritten in place and a monotonic `head` counter (total events
//! ever written) is bumped with `Release` ordering *after* the slot
//! words are stored. Readers copy a window of slots and then re-read
//! `head`; any event whose slot could have been overwritten while the
//! copy was in flight is discarded, so a snapshot never contains a torn
//! event — it just loses a little more of the oldest history, which is
//! exactly the drop-oldest contract already in force.
//!
//! Events are encoded as five `u64` words per slot so the write path is
//! five relaxed stores plus one release store — no CAS, no lock, no
//! allocation after construction.

use std::sync::atomic::{AtomicU64, Ordering};

use super::{Event, EventKind};

/// Words per encoded event: `[ts_us, dur_us, a, b, meta]` where `meta`
/// packs `kind | depth << 8 | tid << 32`.
const WORDS: usize = 5;

/// A consistent copy of one ring: the retained (non-torn) suffix of its
/// history plus the total number of events ever written, so callers can
/// compute how many were dropped (`written - events.len()`).
#[derive(Clone, Debug)]
pub struct RingSnapshot {
    pub tid: u32,
    pub written: u64,
    pub events: Vec<Event>,
}

/// Single-writer, multi-reader, drop-oldest event buffer.
pub struct Ring {
    slots: Box<[[AtomicU64; WORDS]]>,
    /// Total events ever written (monotonic). `head % capacity` is the
    /// next slot to overwrite.
    head: AtomicU64,
    tid: u32,
}

impl Ring {
    /// Minimum capacity: keeps the overwrite-discard window in
    /// `snapshot` from eating an entire tiny ring.
    pub const MIN_CAPACITY: usize = 16;

    pub fn new(capacity: usize, tid: u32) -> Self {
        let cap = capacity.max(Self::MIN_CAPACITY);
        let slots: Vec<[AtomicU64; WORDS]> =
            (0..cap).map(|_| std::array::from_fn(|_| AtomicU64::new(0))).collect();
        Ring { slots: slots.into_boxed_slice(), head: AtomicU64::new(0), tid }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn tid(&self) -> u32 {
        self.tid
    }

    /// Total events ever written to this ring.
    pub fn written(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Record one event. **Owner-thread only**: the ring is single-writer
    /// by construction (each thread owns its own ring); calling this from
    /// two threads concurrently is memory-safe but may interleave slot
    /// words from different events.
    #[inline]
    pub fn push(&self, e: &Event) {
        // Only the owner mutates `head`, so a relaxed read is exact.
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h % self.slots.len() as u64) as usize];
        slot[0].store(e.ts_us, Ordering::Relaxed);
        slot[1].store(e.dur_us, Ordering::Relaxed);
        slot[2].store(e.a, Ordering::Relaxed);
        slot[3].store(e.b, Ordering::Relaxed);
        slot[4].store(encode_meta(e.kind, e.depth, e.tid), Ordering::Relaxed);
        // Release pairs with readers' Acquire on `head`: once a reader
        // observes h+1, the slot words above are visible.
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy out the retained suffix, oldest first. Events whose slot may
    /// have been overwritten while the copy was in flight are discarded
    /// (see module docs), so every returned event is whole.
    pub fn snapshot(&self) -> RingSnapshot {
        let cap = self.slots.len() as u64;
        let h1 = self.head.load(Ordering::Acquire);
        let lo = h1.saturating_sub(cap);
        let mut events = Vec::with_capacity((h1 - lo) as usize);
        for i in lo..h1 {
            let slot = &self.slots[(i % cap) as usize];
            let ts_us = slot[0].load(Ordering::Relaxed);
            let dur_us = slot[1].load(Ordering::Relaxed);
            let a = slot[2].load(Ordering::Relaxed);
            let b = slot[3].load(Ordering::Relaxed);
            let meta = slot[4].load(Ordering::Relaxed);
            let (kind, depth, tid) = decode_meta(meta);
            events.push(Event { ts_us, dur_us, kind, a, b, tid, depth });
        }
        // The writer overwrites event i's slot while writing event
        // i + cap, which begins as soon as head == i + cap (before the
        // bump). With h2 = head after the copy, indices <= h2 - cap may
        // therefore be torn; keep only i >= h2 + 1 - cap.
        let h2 = self.head.load(Ordering::Acquire);
        let safe_lo = (h2 + 1).saturating_sub(cap);
        if safe_lo > lo {
            let drop_n = ((safe_lo - lo) as usize).min(events.len());
            events.drain(..drop_n);
        }
        RingSnapshot { tid: self.tid, written: h2, events }
    }
}

fn encode_meta(kind: EventKind, depth: u16, tid: u32) -> u64 {
    (kind as u64) | ((depth as u64) << 8) | ((tid as u64) << 32)
}

fn decode_meta(meta: u64) -> (EventKind, u16, u32) {
    (EventKind::from_u8(meta as u8), (meta >> 8) as u16, (meta >> 32) as u32)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]
    use super::*;

    fn ev(i: u64) -> Event {
        Event {
            ts_us: i,
            dur_us: 0,
            kind: EventKind::OperatorDispatch,
            a: i,
            b: i * 2,
            tid: 7,
            depth: 3,
        }
    }

    #[test]
    fn push_and_snapshot_roundtrip() {
        let r = Ring::new(64, 7);
        for i in 0..10 {
            r.push(&ev(i));
        }
        let s = r.snapshot();
        assert_eq!(s.written, 10);
        assert_eq!(s.events.len(), 10);
        for (i, e) in s.events.iter().enumerate() {
            assert_eq!(e.a, i as u64);
            assert_eq!(e.b, 2 * i as u64);
            assert_eq!(e.kind, EventKind::OperatorDispatch);
            assert_eq!(e.tid, 7);
            assert_eq!(e.depth, 3);
        }
    }

    #[test]
    fn drop_oldest_retains_exactly_capacity() {
        let cap = 32;
        let r = Ring::new(cap, 0);
        let total = 3 * cap as u64;
        for i in 0..total {
            r.push(&ev(i));
        }
        let s = r.snapshot();
        assert_eq!(s.written, total);
        // A reader cannot know the writer is quiescent, so the snapshot
        // conservatively discards the one slot the writer could have been
        // mid-overwrite on: capacity - 1 retained once wrapped.
        assert_eq!(s.events.len(), cap - 1, "retains capacity - 1 once wrapped");
        // The retained window is the newest `cap - 1` events, oldest first.
        for (j, e) in s.events.iter().enumerate() {
            assert_eq!(e.a, total - (cap as u64 - 1) + j as u64);
        }
    }

    #[test]
    fn tiny_capacity_is_clamped() {
        let r = Ring::new(1, 0);
        assert_eq!(r.capacity(), Ring::MIN_CAPACITY);
    }

    #[test]
    fn concurrent_reader_never_sees_torn_events() {
        // One writer hammering the ring, one reader snapshotting: every
        // event in every snapshot must be internally consistent
        // (b == 2a, valid kind) and in strictly increasing `a` order —
        // the overwrite-discard window is what guarantees this.
        let r = std::sync::Arc::new(Ring::new(64, 1));
        let w = std::sync::Arc::clone(&r);
        let writer = std::thread::spawn(move || {
            for i in 0..200_000u64 {
                w.push(&ev(i));
            }
        });
        let mut checked = 0usize;
        for _ in 0..500 {
            let s = r.snapshot();
            let mut prev: Option<u64> = None;
            for e in &s.events {
                assert_eq!(e.b, e.a * 2, "torn event leaked through snapshot");
                assert_eq!(e.kind, EventKind::OperatorDispatch);
                if let Some(p) = prev {
                    assert!(e.a > p, "snapshot order broken: {p} then {}", e.a);
                }
                prev = Some(e.a);
                checked += 1;
            }
        }
        writer.join().unwrap();
        assert!(checked > 0, "reader should have observed some events");
        // Quiescent snapshot: never lose more than capacity (the reader
        // still discards the one conservatively-torn slot).
        let s = r.snapshot();
        assert_eq!(s.events.len(), r.capacity() - 1);
        assert_eq!(s.written, 200_000);
    }
}
