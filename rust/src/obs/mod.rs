//! Process-wide observability: lock-free event tracing, a unified
//! metrics registry, and exporters (Chrome `trace_event` JSON,
//! Prometheus-style text, flight recorder).
//!
//! Gunrock's contribution is characterization as much as speed — the
//! paper's §7 explains each optimization with per-iteration frontier
//! plots and per-stage timings. This module makes that data a first-class
//! artifact of every run instead of per-layer fragments:
//!
//! - **Tracing ring** ([`ring`]): each thread owns a fixed-capacity,
//!   drop-oldest event buffer (the tracing analog of the pool's scratch
//!   recycler). Emitting an event is a handful of relaxed stores — no
//!   lock, no allocation, no blocking — so instrumentation can sit on the
//!   operator-dispatch and BSP-iteration hot paths. Spans are recorded at
//!   the existing seams: operator dispatch, BSP iteration boundaries
//!   (piggybacked on the budget `proceed()` check), load-balance
//!   strategy / frontier-mode decisions, batcher drain, queue
//!   admission / shed / coalesce, and `.gsr` decode.
//! - **Metrics registry** ([`registry`]): counters / gauges / fixed-bucket
//!   histograms fed by every primitive's `RunResult` (absorbing the
//!   `WarpCounters`-derived fields) and folded together with the service
//!   `StatsSnapshot` at export time.
//! - **Exporters** ([`export`], [`recorder`]): `--trace out.json` writes a
//!   Chrome trace; the serve protocol's `metrics` command returns a JSON
//!   stats line plus a Prometheus-style text snapshot; the flight
//!   recorder dumps the last N ring events on budget trips, batcher
//!   panics, and load shedding.
//!
//! **Gating discipline** (same contract as `util/faults.rs`, but runtime-
//! switchable because `--trace` must work on release binaries): every
//! entry point starts with a single relaxed load of a static enable flag
//! and returns immediately when off — no ring is ever created, no clock
//! is read, nothing allocates. The `ablation_observability` bench gates
//! the armed overhead at < 3 %.

pub mod export;
pub mod recorder;
pub mod registry;
pub mod ring;

pub use recorder::{flight_dump, last_flight_dump};
pub use registry::{metrics, Counter, Gauge, Histogram, MetricSnapshot, MetricValue, Registry};
pub use ring::{Ring, RingSnapshot};

use std::cell::{Cell, OnceCell};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Poison-immune lock (observability must survive panics elsewhere —
/// that is when the flight recorder is most needed).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

// ---------------------------------------------------------------------------
// Enable gate + configuration
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Capacity for rings created after this point (existing rings keep the
/// capacity they were born with).
static RING_CAP: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAPACITY);

pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// The static enable check every instrumentation point starts with.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Apply the `obs.*` config knobs: ring capacity first (so rings created
/// by freshly spawned threads see it), then the enable flag.
pub fn configure(enable: bool, ring_capacity: usize) {
    RING_CAP.store(ring_capacity.clamp(Ring::MIN_CAPACITY, 1 << 24), Ordering::Relaxed);
    ENABLED.store(enable, Ordering::Relaxed);
}

/// Process-relative monotonic clock, microseconds. All event timestamps
/// share this epoch so cross-thread ordering in a trace is meaningful.
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

// ---------------------------------------------------------------------------
// Events and spans
// ---------------------------------------------------------------------------

/// What an event is about. The two payload words `a` / `b` are
/// kind-specific; [`EventKind::arg_names`] documents them per kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Decoding fallback for a torn/garbage meta word; never emitted.
    Unknown = 0,
    /// Load-balance dispatch of one operator pass. a = strategy tag
    /// (see [`strategy_name`]), b = input items.
    OperatorDispatch = 1,
    /// One participant's share of a pool broadcast. a = logical worker
    /// count, b = ids this participant claimed.
    WorkerJob = 2,
    /// One push-mode BSP iteration. a = input frontier, b = output
    /// frontier; duration is the iteration wall time.
    BspIteration = 3,
    /// One pull-mode BSP iteration (same payloads as [`Self::BspIteration`]).
    BspIterationPull = 4,
    /// Load-balance strategy decision. a = strategy tag, b = frontier len.
    LbStrategy = 5,
    /// Frontier representation decision. a = 1 dense / 0 sparse,
    /// b = frontier len.
    FrontierMode = 6,
    /// One primitive run end-to-end. a = primitive tag (see
    /// [`prim_name`]), b = lanes.
    PrimitiveRun = 7,
    /// `.gsr` container decode. a = payload bytes, b = 0.
    GsrDecode = 8,
    /// Query admitted into the service queue. a = primitive tag,
    /// b = queue depth after admission.
    QueueAdmit = 9,
    /// Query coalesced onto an in-flight ticket. a = primitive tag,
    /// b = source.
    QueueCoalesce = 10,
    /// Query rejected at admission. a = primitive tag, b = queue depth.
    QueueReject = 11,
    /// Query shed for queue age. a = primitive tag, b = queued ms.
    QueueShed = 12,
    /// Landmark-cache hit at admission. a = primitive tag, b = source.
    CacheHit = 13,
    /// Batcher drained one same-kind batch. a = primitive tag,
    /// b = batch size.
    BatcherDrain = 14,
    /// A run budget tripped. a = completed iterations, b = interrupt tag
    /// (see [`interrupt_name`]).
    BudgetTrip = 15,
    /// Degradation-ladder transition. a = new level (0 normal … 4 shed),
    /// b = pressure percent at the transition.
    GovernorLadder = 16,
    /// Resource-governor refusal. a = requested bytes, b = ladder level.
    GovernorDeny = 17,
}

impl EventKind {
    pub fn from_u8(v: u8) -> EventKind {
        match v {
            1 => EventKind::OperatorDispatch,
            2 => EventKind::WorkerJob,
            3 => EventKind::BspIteration,
            4 => EventKind::BspIterationPull,
            5 => EventKind::LbStrategy,
            6 => EventKind::FrontierMode,
            7 => EventKind::PrimitiveRun,
            8 => EventKind::GsrDecode,
            9 => EventKind::QueueAdmit,
            10 => EventKind::QueueCoalesce,
            11 => EventKind::QueueReject,
            12 => EventKind::QueueShed,
            13 => EventKind::CacheHit,
            14 => EventKind::BatcherDrain,
            15 => EventKind::BudgetTrip,
            16 => EventKind::GovernorLadder,
            17 => EventKind::GovernorDeny,
            _ => EventKind::Unknown,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EventKind::Unknown => "unknown",
            EventKind::OperatorDispatch => "operator_dispatch",
            EventKind::WorkerJob => "worker_job",
            EventKind::BspIteration => "bsp_iteration",
            EventKind::BspIterationPull => "bsp_iteration_pull",
            EventKind::LbStrategy => "lb_strategy",
            EventKind::FrontierMode => "frontier_mode",
            EventKind::PrimitiveRun => "primitive_run",
            EventKind::GsrDecode => "gsr_decode",
            EventKind::QueueAdmit => "queue_admit",
            EventKind::QueueCoalesce => "queue_coalesce",
            EventKind::QueueReject => "queue_reject",
            EventKind::QueueShed => "queue_shed",
            EventKind::CacheHit => "cache_hit",
            EventKind::BatcherDrain => "batcher_drain",
            EventKind::BudgetTrip => "budget_trip",
            EventKind::GovernorLadder => "governor_ladder",
            EventKind::GovernorDeny => "governor_deny",
        }
    }

    /// Semantic names for the `a` / `b` payloads (trace-viewer args).
    pub fn arg_names(self) -> (&'static str, &'static str) {
        match self {
            EventKind::OperatorDispatch => ("strategy", "items"),
            EventKind::WorkerJob => ("workers", "claimed"),
            EventKind::BspIteration | EventKind::BspIterationPull => {
                ("input_frontier", "output_frontier")
            }
            EventKind::LbStrategy => ("strategy", "frontier_len"),
            EventKind::FrontierMode => ("dense", "frontier_len"),
            EventKind::PrimitiveRun => ("primitive", "lanes"),
            EventKind::GsrDecode => ("bytes", "b"),
            EventKind::QueueAdmit | EventKind::QueueReject => ("primitive", "queue_depth"),
            EventKind::QueueCoalesce | EventKind::CacheHit => ("primitive", "source"),
            EventKind::QueueShed => ("primitive", "queued_ms"),
            EventKind::BatcherDrain => ("primitive", "batch"),
            EventKind::BudgetTrip => ("iteration", "interrupt"),
            EventKind::GovernorLadder => ("level", "pressure_pct"),
            EventKind::GovernorDeny => ("bytes", "level"),
            EventKind::Unknown => ("a", "b"),
        }
    }

    /// Instant events render as `ph:"i"` in Chrome traces; the rest are
    /// complete (`ph:"X"`) spans.
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            EventKind::LbStrategy
                | EventKind::FrontierMode
                | EventKind::QueueAdmit
                | EventKind::QueueCoalesce
                | EventKind::QueueReject
                | EventKind::QueueShed
                | EventKind::CacheHit
                | EventKind::BudgetTrip
                | EventKind::GovernorLadder
                | EventKind::GovernorDeny
        )
    }
}

/// One trace event. `depth` is the span-nesting depth on the emitting
/// thread at record time (0 = outermost), which lets a reader validate
/// the span tree independent of timestamps.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub ts_us: u64,
    pub dur_us: u64,
    pub kind: EventKind,
    pub a: u64,
    pub b: u64,
    pub tid: u32,
    pub depth: u16,
}

// ---------------------------------------------------------------------------
// Per-thread rings
// ---------------------------------------------------------------------------

static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static LOCAL_RING: OnceCell<Arc<Ring>> = const { OnceCell::new() };
    static DEPTH: Cell<u16> = const { Cell::new(0) };
}

/// Run `f` against this thread's ring, creating + registering it on
/// first use (the only locking step, once per thread lifetime). Returns
/// `None` if thread-local storage is already torn down.
fn with_local_ring<R>(f: impl FnOnce(&Ring) -> R) -> Option<R> {
    LOCAL_RING
        .try_with(|cell| {
            let ring = cell.get_or_init(|| {
                let r = Arc::new(Ring::new(
                    RING_CAP.load(Ordering::Relaxed),
                    NEXT_TID.fetch_add(1, Ordering::Relaxed),
                ));
                lock(&RINGS).push(Arc::clone(&r));
                r
            });
            f(ring)
        })
        .ok()
}

fn current_depth() -> u16 {
    DEPTH.try_with(Cell::get).unwrap_or(0)
}

fn emit_raw(kind: EventKind, ts_us: u64, dur_us: u64, a: u64, b: u64) {
    let depth = current_depth();
    let _ = with_local_ring(|ring| {
        ring.push(&Event { ts_us, dur_us, kind, a, b, tid: ring.tid(), depth });
    });
}

/// Record an instant event (duration 0).
#[inline]
pub fn event(kind: EventKind, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    emit_raw(kind, now_us(), 0, a, b);
}

/// Record a complete event whose duration is already known (the span
/// started `dur_us` ago): used where a caller measures its own elapsed
/// time anyway, e.g. the enactor's per-iteration timer.
#[inline]
pub fn event_with_dur(kind: EventKind, dur_us: u64, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    let now = now_us();
    emit_raw(kind, now.saturating_sub(dur_us), dur_us, a, b);
}

/// RAII span: records one complete event covering its own lifetime when
/// dropped. Disarmed (free) when tracing is disabled at creation.
#[must_use = "a span records its duration when dropped"]
pub struct Span {
    kind: EventKind,
    a: u64,
    b: u64,
    start_us: u64,
    armed: bool,
}

/// Open a span. The nesting depth recorded with the event is the depth
/// at open time; nested spans opened while this one is live record
/// depth + 1, which is how the exporters reconstruct the tree.
#[inline]
pub fn span(kind: EventKind, a: u64, b: u64) -> Span {
    if !enabled() {
        return Span { kind, a, b, start_us: 0, armed: false };
    }
    let armed = DEPTH.try_with(|d| d.set(d.get().saturating_add(1))).is_ok();
    Span { kind, a, b, start_us: now_us(), armed }
}

impl Span {
    /// Update the `b` payload before the span closes (e.g. a result
    /// count only known at the end).
    pub fn set_b(&mut self, b: u64) {
        self.b = b;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // Re-balance depth even if tracing was switched off mid-span.
        let open_depth = DEPTH
            .try_with(|d| {
                let v = d.get().saturating_sub(1);
                d.set(v);
                v
            })
            .unwrap_or(0);
        if !enabled() {
            return;
        }
        let now = now_us();
        let dur = now.saturating_sub(self.start_us);
        let _ = with_local_ring(|ring| {
            ring.push(&Event {
                ts_us: self.start_us,
                dur_us: dur,
                kind: self.kind,
                a: self.a,
                b: self.b,
                tid: ring.tid(),
                depth: open_depth,
            });
        });
    }
}

/// Snapshot every registered ring (one per thread that ever emitted).
pub fn snapshot_all() -> Vec<RingSnapshot> {
    lock(&RINGS).iter().map(|r| r.snapshot()).collect()
}

/// All retained events across every ring, sorted by timestamp.
pub fn all_events_sorted() -> Vec<Event> {
    let mut out: Vec<Event> = snapshot_all().into_iter().flat_map(|s| s.events).collect();
    out.sort_by_key(|e| (e.ts_us, e.tid));
    out
}

/// Total events ever written across all rings (including dropped ones);
/// the bench uses the delta of this as its events/sec denominator.
pub fn total_events_written() -> u64 {
    lock(&RINGS).iter().map(|r| r.written()).sum()
}

// ---------------------------------------------------------------------------
// Tag tables (stable u64 payload encodings for cross-layer enums; obs
// sits below those layers, so they map *into* these tags — parity tests
// live next to each enum)
// ---------------------------------------------------------------------------

/// Primitive tags: same order as `primitives::api::PrimitiveKind`.
pub mod tags {
    pub const BFS: u64 = 0;
    pub const SSSP: u64 = 1;
    pub const BC: u64 = 2;
    pub const PAGERANK: u64 = 3;
    pub const CC: u64 = 4;
    pub const TC: u64 = 5;
    pub const WTF: u64 = 6;
    pub const PPR: u64 = 7;
    pub const MST: u64 = 8;
    pub const COLOR: u64 = 9;
    pub const MIS: u64 = 10;
    pub const LP: u64 = 11;
    pub const RADII: u64 = 12;

    /// Display names, indexed by tag.
    pub const NAMES: [&str; 13] = [
        "bfs", "sssp", "bc", "pagerank", "cc", "tc", "wtf", "ppr", "mst", "color", "mis", "lp",
        "radii",
    ];
}

/// Name for a primitive tag (tags beyond the table render as "?").
pub fn prim_name(tag: u64) -> &'static str {
    tags::NAMES.get(tag as usize).copied().unwrap_or("?")
}

/// Name for a load-balance strategy tag (`StrategyKind as u64`).
pub fn strategy_name(tag: u64) -> &'static str {
    match tag {
        0 => "thread_expand",
        1 => "twc",
        2 => "lb",
        3 => "lb_light",
        4 => "lb_cull",
        _ => "?",
    }
}

/// Name for an interrupt tag (`Interrupt` discriminant order).
pub fn interrupt_name(tag: u64) -> &'static str {
    match tag {
        0 => "deadline",
        1 => "cancelled",
        2 => "iteration_budget",
        _ => "?",
    }
}

// ---------------------------------------------------------------------------
// RunResult feed
// ---------------------------------------------------------------------------

struct KindMetrics {
    runs: Counter,
    interrupted: Counter,
    edges: Counter,
    iterations: Counter,
    latency: Histogram,
}

struct RunFeed {
    per_kind: Vec<KindMetrics>,
    kernel_launches: Counter,
    atomics: Counter,
    lanes: Counter,
    warp_efficiency: Gauge,
}

fn run_feed() -> &'static RunFeed {
    static FEED: OnceLock<RunFeed> = OnceLock::new();
    FEED.get_or_init(|| {
        let r = metrics();
        let per_kind = tags::NAMES
            .iter()
            .map(|name| KindMetrics {
                runs: r.counter(&format!("runs_total{{kind=\"{name}\"}}")),
                interrupted: r.counter(&format!("runs_interrupted_total{{kind=\"{name}\"}}")),
                edges: r.counter(&format!("edges_visited_total{{kind=\"{name}\"}}")),
                iterations: r.counter(&format!("bsp_iterations_total{{kind=\"{name}\"}}")),
                latency: r.histogram_ms(&format!("run_ms{{kind=\"{name}\"}}")),
            })
            .collect();
        RunFeed {
            per_kind,
            kernel_launches: r.counter("kernel_launches_total"),
            atomics: r.counter("atomics_total"),
            lanes: r.counter("lanes_total"),
            warp_efficiency: r.gauge("warp_efficiency_last"),
        }
    })
}

/// Feed one primitive `RunResult` into the registry (called by the api
/// dispatchers for every run; scalar arguments because obs sits below
/// the enactor). Absorbs the `WarpCounters`-derived fields
/// (kernel launches, atomics, warp efficiency) that used to be visible
/// only on the per-run struct.
#[allow(clippy::too_many_arguments)]
pub fn record_run(
    prim_tag: u64,
    runtime_ms: f64,
    edges_visited: u64,
    iterations: u64,
    lanes: u64,
    warp_efficiency: f64,
    kernel_launches: u64,
    atomics: u64,
    interrupted: bool,
) {
    if !enabled() {
        return;
    }
    let feed = run_feed();
    let idx = (prim_tag as usize).min(feed.per_kind.len() - 1);
    let m = &feed.per_kind[idx];
    m.runs.inc();
    if interrupted {
        m.interrupted.inc();
    }
    m.edges.add(edges_visited);
    m.iterations.add(iterations);
    m.latency.observe_ms(runtime_ms);
    feed.kernel_launches.add(kernel_launches);
    feed.atomics.add(atomics);
    feed.lanes.add(lanes.max(1));
    feed.warp_efficiency.set(warp_efficiency);
}

/// Tests that toggle the process-global enable flag serialize on this
/// guard (same discipline as the `util::faults` tests).
#[cfg(test)]
pub(crate) mod test_guard {
    use std::sync::{Mutex, MutexGuard};

    static GUARD: Mutex<()> = Mutex::new(());

    pub fn hold() -> MutexGuard<'static, ()> {
        match GUARD.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]
    use super::*;

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        test_guard::hold()
    }

    #[test]
    fn disabled_emit_is_a_noop() {
        let _g = guard();
        set_enabled(false);
        let before = total_events_written();
        for _ in 0..100 {
            event(EventKind::QueueAdmit, 1, 2);
            let _s = span(EventKind::OperatorDispatch, 0, 0);
        }
        assert_eq!(total_events_written(), before, "disabled mode must emit nothing");
    }

    #[test]
    fn span_records_duration_and_depth() {
        let _g = guard();
        set_enabled(true);
        let marker = 0xC0FFEE;
        {
            let _outer = span(EventKind::PrimitiveRun, marker, 0);
            let _inner = span(EventKind::OperatorDispatch, marker, 1);
        }
        set_enabled(false);
        let evs = all_events_sorted();
        let outer = evs
            .iter()
            .find(|e| e.kind == EventKind::PrimitiveRun && e.a == marker)
            .expect("outer span recorded");
        let inner = evs
            .iter()
            .find(|e| e.kind == EventKind::OperatorDispatch && e.a == marker)
            .expect("inner span recorded");
        assert_eq!(outer.depth, 0);
        assert_eq!(inner.depth, 1);
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us);
    }

    #[test]
    fn kind_roundtrips_through_meta_byte() {
        for v in 0..=20u8 {
            let k = EventKind::from_u8(v);
            if k != EventKind::Unknown {
                assert_eq!(k as u8, v);
                assert_ne!(k.name(), "unknown");
            }
        }
    }

    #[test]
    fn tag_names_cover_all_primitives() {
        assert_eq!(tags::NAMES.len(), 13);
        assert_eq!(prim_name(tags::PPR), "ppr");
        assert_eq!(prim_name(999), "?");
        assert_eq!(strategy_name(4), "lb_cull");
        assert_eq!(interrupt_name(0), "deadline");
    }

    #[test]
    fn record_run_feeds_registry() {
        let _g = guard();
        set_enabled(true);
        record_run(tags::BFS, 1.5, 1000, 7, 1, 0.9, 12, 34, false);
        record_run(tags::BFS, 2.5, 2000, 8, 1, 0.8, 1, 1, true);
        set_enabled(false);
        let snap = metrics().snapshot();
        let get = |name: &str| {
            snap.iter().find(|m| m.name == name).map(|m| match m.value {
                MetricValue::Counter(v) => v,
                _ => panic!("expected counter {name}"),
            })
        };
        assert!(get("runs_total{kind=\"bfs\"}").unwrap() >= 2);
        assert!(get("runs_interrupted_total{kind=\"bfs\"}").unwrap() >= 1);
        assert!(get("edges_visited_total{kind=\"bfs\"}").unwrap() >= 3000);
    }
}
