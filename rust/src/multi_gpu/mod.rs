//! Multi-GPU scale-out simulation (paper §8.2.1 / Pan et al. [56]): the
//! single-device data-centric core stays unchanged; a partition layer
//! assigns vertices to virtual devices and a communication layer exchanges
//! remote frontiers between BSP supersteps, accounting bytes moved —
//! reproducing the paper's "tradeoffs between computation and
//! communication for inter-GPU data exchange".

pub mod partition;

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::config::Config;
use crate::graph::{Csr, VertexId};
use crate::util::timer::Timer;

pub use partition::{partition, PartitionMethod, Partitioning};

/// Per-device + communication statistics for a multi-device run.
#[derive(Clone, Debug, Default)]
pub struct MultiGpuStats {
    pub devices: usize,
    pub runtime_ms: f64,
    pub iterations: usize,
    /// Edges relaxed per device (computation balance).
    pub edges_per_device: Vec<u64>,
    /// Total remote-frontier vertices exchanged (communication volume).
    pub vertices_exchanged: u64,
    /// Bytes moved between devices (4 B per vertex id + 4 B per label).
    pub bytes_exchanged: u64,
}

impl MultiGpuStats {
    /// Computation balance: min/max edges across devices (1.0 = perfect).
    pub fn compute_balance(&self) -> f64 {
        let max = self.edges_per_device.iter().copied().max().unwrap_or(0);
        let min = self.edges_per_device.iter().copied().min().unwrap_or(0);
        if max == 0 {
            1.0
        } else {
            min as f64 / max as f64
        }
    }
}

/// Multi-device BFS: each virtual device owns a vertex partition and
/// expands only its local frontier slice; discoveries of remote vertices
/// are buffered and exchanged at the superstep barrier (the paper's
/// multi-GPU execution model with an unchanged single-device core).
pub fn multi_gpu_bfs(
    g: &Csr,
    src: VertexId,
    parts: &Partitioning,
    _config: &Config,
) -> (Vec<u32>, MultiGpuStats) {
    let n = g.num_vertices;
    let d = parts.num_parts;
    let t = Timer::start();

    let labels: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    labels[src as usize].store(0, Ordering::Relaxed);
    let edges_per_device: Vec<AtomicU64> = (0..d).map(|_| AtomicU64::new(0)).collect();
    let mut vertices_exchanged = 0u64;

    // per-device local frontiers
    let mut frontiers: Vec<Vec<VertexId>> = vec![Vec::new(); d];
    frontiers[parts.owner(src)].push(src);

    let mut depth = 0u32;
    let mut iterations = 0usize;
    while frontiers.iter().any(|f| !f.is_empty()) {
        iterations += 1;
        depth += 1;
        // Each device expands its local frontier; remote discoveries go
        // to that device's outbox (one outbox per peer).
        let mut outboxes: Vec<Vec<Vec<VertexId>>> = vec![vec![Vec::new(); d]; d];
        let mut next_local: Vec<Vec<VertexId>> = vec![Vec::new(); d];
        for dev in 0..d {
            let frontier = std::mem::take(&mut frontiers[dev]);
            for &v in &frontier {
                edges_per_device[dev].fetch_add(g.degree(v) as u64, Ordering::Relaxed);
                for &u in g.neighbors(v) {
                    if labels[u as usize]
                        .compare_exchange(u32::MAX, depth, Ordering::Relaxed, Ordering::Relaxed)
                        .is_ok()
                    {
                        let owner = parts.owner(u);
                        if owner == dev {
                            next_local[dev].push(u);
                        } else {
                            outboxes[dev][owner].push(u);
                        }
                    }
                }
            }
        }
        // Superstep barrier: exchange outboxes.
        for dev in 0..d {
            frontiers[dev] = std::mem::take(&mut next_local[dev]);
            for sender in 0..d {
                let incoming = std::mem::take(&mut outboxes[sender][dev]);
                vertices_exchanged += incoming.len() as u64;
                frontiers[dev].extend(incoming);
            }
        }
    }

    let stats = MultiGpuStats {
        devices: d,
        runtime_ms: t.elapsed_ms(),
        iterations,
        edges_per_device: edges_per_device.into_iter().map(|a| a.into_inner()).collect(),
        vertices_exchanged,
        bytes_exchanged: vertices_exchanged * 8,
    };
    (labels.into_iter().map(|a| a.into_inner()).collect(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::bfs_serial::bfs_serial;
    use crate::graph::datasets;

    #[test]
    fn multi_device_bfs_matches_serial() {
        let g = datasets::load("kron_g500-logn10", false);
        let want = bfs_serial(&g, 0);
        for d in [1usize, 2, 4] {
            for method in [PartitionMethod::Random, PartitionMethod::Contiguous] {
                let parts = partition(&g, d, method, 42);
                let (got, stats) = multi_gpu_bfs(&g, 0, &parts, &Config::default());
                assert_eq!(got, want, "d={d} {method:?}");
                assert_eq!(stats.devices, d);
                if d == 1 {
                    assert_eq!(stats.vertices_exchanged, 0);
                }
            }
        }
    }

    #[test]
    fn communication_grows_with_devices() {
        let g = datasets::load("kron_g500-logn10", false);
        let p2 = partition(&g, 2, PartitionMethod::Random, 42);
        let p4 = partition(&g, 4, PartitionMethod::Random, 42);
        let (_, s2) = multi_gpu_bfs(&g, 0, &p2, &Config::default());
        let (_, s4) = multi_gpu_bfs(&g, 0, &p4, &Config::default());
        assert!(s4.vertices_exchanged > s2.vertices_exchanged);
    }

    #[test]
    fn random_partition_balances_compute() {
        let g = datasets::load("rmat_s22_e64", false);
        let parts = partition(&g, 4, PartitionMethod::Random, 7);
        let (_, stats) = multi_gpu_bfs(&g, crate::harness::suite::pick_source(&g), &parts, &Config::default());
        assert!(stats.compute_balance() > 0.5, "balance {}", stats.compute_balance());
    }
}
