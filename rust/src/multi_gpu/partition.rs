//! Vertex partitioning for the multi-device simulation: random hashing
//! (the paper's multi-GPU work found random vertex assignment gives the
//! best compute balance on scale-free graphs) vs contiguous ranges
//! (locality-preserving, less communication on meshes) — the partitioning
//! tradeoff §8.2.1 poses as an open question.

use crate::graph::{Csr, VertexId};
use crate::util::rng::Pcg32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionMethod {
    Random,
    Contiguous,
    /// Greedy degree-balanced: assign vertices (heaviest first) to the
    /// device with the least total degree — a cheap vertex-cut-flavored
    /// balance heuristic.
    DegreeBalanced,
}

pub struct Partitioning {
    pub num_parts: usize,
    pub assignment: Vec<u16>,
    /// Fraction of edges crossing partitions.
    pub edge_cut: f64,
}

impl Partitioning {
    #[inline]
    pub fn owner(&self, v: VertexId) -> usize {
        self.assignment[v as usize] as usize
    }
}

pub fn partition(g: &Csr, parts: usize, method: PartitionMethod, seed: u64) -> Partitioning {
    assert!(parts >= 1 && parts <= u16::MAX as usize);
    let n = g.num_vertices;
    let assignment: Vec<u16> = match method {
        PartitionMethod::Random => {
            let mut rng = Pcg32::new(seed);
            (0..n).map(|_| rng.below(parts as u32) as u16).collect()
        }
        PartitionMethod::Contiguous => {
            let per = n.div_ceil(parts);
            (0..n).map(|v| (v / per) as u16).collect()
        }
        PartitionMethod::DegreeBalanced => {
            let mut order: Vec<VertexId> = (0..n as VertexId).collect();
            order.sort_unstable_by_key(|&v| std::cmp::Reverse(g.degree(v)));
            let mut load = vec![0u64; parts];
            let mut assignment = vec![0u16; n];
            for v in order {
                let dev = (0..parts).min_by_key(|&p| load[p]).unwrap();
                assignment[v as usize] = dev as u16;
                load[dev] += g.degree(v) as u64 + 1;
            }
            assignment
        }
    };
    // edge cut
    let mut cut = 0u64;
    for v in 0..n as VertexId {
        for &u in g.neighbors(v) {
            if assignment[v as usize] != assignment[u as usize] {
                cut += 1;
            }
        }
    }
    let edge_cut = if g.num_edges() == 0 { 0.0 } else { cut as f64 / g.num_edges() as f64 };
    Partitioning { num_parts: parts, assignment, edge_cut }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::graph::generators::{grid::GridParams, grid2d};

    #[test]
    fn all_methods_cover_all_parts() {
        let g = datasets::load("kron_g500-logn9", false);
        for m in [PartitionMethod::Random, PartitionMethod::Contiguous, PartitionMethod::DegreeBalanced] {
            let p = partition(&g, 4, m, 1);
            let mut seen = [false; 4];
            for &a in &p.assignment {
                seen[a as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "{m:?}");
            assert!((0.0..=1.0).contains(&p.edge_cut));
        }
    }

    #[test]
    fn contiguous_cuts_fewer_mesh_edges_than_random() {
        let g = grid2d(&GridParams { width: 64, height: 64, ..Default::default() });
        let pr = partition(&g, 4, PartitionMethod::Random, 5);
        let pc = partition(&g, 4, PartitionMethod::Contiguous, 5);
        assert!(
            pc.edge_cut < pr.edge_cut / 2.0,
            "contiguous {} vs random {}",
            pc.edge_cut,
            pr.edge_cut
        );
    }

    #[test]
    fn degree_balanced_balances_degrees() {
        let g = datasets::load("rmat_s22_e64", false);
        let p = partition(&g, 4, PartitionMethod::DegreeBalanced, 3);
        let mut load = [0u64; 4];
        for v in 0..g.num_vertices as u32 {
            load[p.owner(v)] += g.degree(v) as u64;
        }
        let max = *load.iter().max().unwrap() as f64;
        let min = *load.iter().min().unwrap() as f64;
        assert!(min / max > 0.9, "{load:?}");
    }

    #[test]
    fn single_part_zero_cut() {
        let g = datasets::load("kron_g500-logn8", false);
        let p = partition(&g, 1, PartitionMethod::Random, 1);
        assert_eq!(p.edge_cut, 0.0);
    }
}
