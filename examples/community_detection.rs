//! Community detection and scheduling example: label propagation for
//! communities, Jones-Plassmann coloring and MIS for conflict-free
//! scheduling, and Borůvka MST for backbone extraction — the paper's
//! §8.2.4 extension primitives working together on a social-graph analog.
//!
//!     cargo run --release --example community_detection

use gunrock::config::Config;
use gunrock::graph::datasets;
use gunrock::primitives::{color, label_propagation, mst};

fn main() {
    let cfg = Config::default();
    let g = datasets::load("soc-livejournal1", true);
    println!("graph: {} vertices, {} edges\n", g.num_vertices, g.num_edges());

    // Communities via label propagation.
    let (lp, r) = label_propagation::label_propagation(&g, &cfg);
    let mut sizes: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    for &l in &lp.labels {
        *sizes.entry(l).or_insert(0) += 1;
    }
    let mut top: Vec<usize> = sizes.values().copied().collect();
    top.sort_unstable_by(|a, b| b.cmp(a));
    println!(
        "[LP]    {} communities in {} iterations ({:.1} ms); largest: {:?}",
        lp.num_communities,
        lp.iterations,
        r.runtime_ms,
        &top[..top.len().min(5)]
    );

    // Greedy coloring (conflict-free update schedule).
    let (col, r) = color::color(&g, &cfg);
    println!("[COLOR] {} colors in {:.1} ms (max degree {} bounds it above)", col.num_colors, r.runtime_ms,
        (0..g.num_vertices as u32).map(|v| g.degree(v)).max().unwrap());

    // Maximal independent set.
    let (in_mis, r) = color::mis(&g, &cfg);
    println!("[MIS]   {} vertices independent ({:.1} ms)", in_mis.iter().filter(|&&b| b).count(), r.runtime_ms);

    // Minimum spanning forest as a community backbone.
    let (m, r) = mst::mst(&g, &cfg);
    println!(
        "[MST]   forest of {} edges, total weight {} ({:.1} ms)",
        m.tree_edges.len(),
        m.total_weight,
        r.runtime_ms
    );
    println!("\nall extension primitives complete");
}
