//! End-to-end driver (DESIGN.md "End-to-end"): a full social-network
//! analytics pipeline on an R-MAT social-graph analog, proving all layers
//! compose — reachability (BFS), influence ranking (PageRank), community
//! structure (CC), recommendation (WTF), and clustering (TC) — reporting
//! runtime + MTEPS per stage.
//!
//!     cargo run --release --example social_ranking

use gunrock::config::Config;
use gunrock::graph::datasets;
use gunrock::harness::suite;
use gunrock::primitives::{bfs, cc, pagerank, tc, wtf};

fn main() {
    let cfg = Config::default();
    println!("== Social-network analytics pipeline (end-to-end driver) ==\n");

    // Stage 0: workload (soc-livejournal1 analog, Table 4 class rs).
    let g = datasets::load("soc-livejournal1", false);
    println!("[0] dataset soc-livejournal1 analog: {} vertices, {} edges", g.num_vertices, g.num_edges());

    // Stage 1: reachability from the most-connected user.
    let src = suite::pick_source(&g);
    let mut bfs_cfg = cfg.clone();
    bfs_cfg.direction_optimized = true;
    let (labels, st) = bfs::bfs(&g, src, &bfs_cfg);
    let reached = labels.labels.iter().filter(|&&d| d != bfs::INFINITY_DEPTH).count();
    println!(
        "[1] BFS reachability: {reached} reachable from {src} | {:.2} ms | {:.0} MTEPS",
        st.result.runtime_ms,
        st.result.mteps()
    );

    // Stage 2: influence ranking (full PageRank to convergence).
    let mut pr_cfg = cfg.clone();
    pr_cfg.pr_max_iters = 50;
    let (pr, r) = pagerank::pagerank(&g, &pr_cfg);
    let mut top: Vec<usize> = (0..g.num_vertices).collect();
    top.sort_unstable_by(|&a, &b| pr.ranks[b].partial_cmp(&pr.ranks[a]).unwrap());
    println!(
        "[2] PageRank: {} iterations | {:.2} ms | top influencers {:?}",
        pr.iterations,
        r.runtime_ms,
        &top[..5]
    );

    // Stage 3: community structure.
    let (comps, r) = cc::cc(&g, &cfg);
    println!("[3] CC: {} components | {:.2} ms", comps.num_components, r.runtime_ms);

    // Stage 4: who-to-follow recommendation for the top influencer.
    let user = top[0] as u32;
    let (recs, r) = wtf::wtf(&g, user, 100, 5, &cfg);
    println!(
        "[4] WTF for user {user}: recommend {:?} | total {:.2} ms (ppr {:.2} / cot {:.2} / money {:.2})",
        recs.recommendations, r.runtime_ms, recs.ppr_ms, recs.cot_ms, recs.money_ms
    );

    // Stage 5: clustering (triangle census).
    let (tcr, r) = tc::tc_intersect_filtered(&g, &cfg);
    println!("[5] TC: {} triangles | {:.2} ms | {:.0} MTEPS", tcr.triangles, r.runtime_ms, r.mteps());

    println!("\npipeline complete — all stages green (record in EXPERIMENTS.md §End-to-end)");
}
