//! XLA offload demo: the three-layer request path. PageRank and pull-BFS
//! execute through AOT artifacts — Pallas kernel (L1) fused into the JAX
//! step function (L2), lowered to HLO text at build time, loaded and run
//! here by the Rust coordinator (L3) via PJRT. Python is not involved.
//!
//!     make artifacts && cargo run --release --example gpu_offload

use gunrock::baselines::{bfs_serial::bfs_serial, pagerank_serial::pagerank_serial};
use gunrock::graph::datasets;
use gunrock::runtime::XlaRuntime;
use gunrock::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let mut rt = XlaRuntime::new(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}\n", rt.platform());

    for name in ["grid_1k", "rgg_1k", "grid_4k"] {
        let g = datasets::load(name, false);
        println!("dataset {name}: {} vertices, {} edges", g.num_vertices, g.num_edges());

        // PageRank through the artifact vs CPU reference.
        let t = Timer::start();
        let (ranks, iters) = rt.pagerank(&g, 0.0, 20)?;
        let xla_ms = t.elapsed_ms();
        let t = Timer::start();
        let want = pagerank_serial(&g, 0.85, 20, 0.0);
        let cpu_ms = t.elapsed_ms();
        let max_err = ranks
            .iter()
            .zip(&want)
            .map(|(&a, &b)| (a as f64 - b).abs())
            .fold(0.0f64, f64::max);
        println!("  PR   : xla {xla_ms:7.2} ms ({iters} iters) | cpu {cpu_ms:6.2} ms | max|err| {max_err:.2e}");

        // Pull-BFS through the artifact vs serial reference.
        let t = Timer::start();
        let (depth, steps) = rt.bfs_pull(&g, 0, 5000)?;
        let xla_ms = t.elapsed_ms();
        assert_eq!(depth, bfs_serial(&g, 0), "{name}: XLA BFS disagrees");
        println!("  BFS  : xla {xla_ms:7.2} ms ({steps} pull steps) | matches serial reference\n");
    }
    println!("all artifacts agree with CPU references");
    Ok(())
}
