//! Road-network navigation: SSSP with the near/far priority queue on the
//! roadnet analog (large-diameter mesh — the workload class where
//! delta-stepping and TWC matter), compared against Dijkstra and
//! Bellman-Ford, plus a shortest-path extraction.
//!
//!     cargo run --release --example road_navigation

use gunrock::baselines::{bellman_ford::bellman_ford, dijkstra::dijkstra};
use gunrock::config::Config;
use gunrock::graph::datasets;
use gunrock::load_balance::StrategyKind;
use gunrock::primitives::sssp;
use gunrock::util::timer::time_ms;

fn main() {
    let g = datasets::load("roadnet_USA", true);
    println!(
        "road network analog: {} vertices, {} edges (weighted 1..64)",
        g.num_vertices,
        g.num_edges()
    );
    let src = 0u32;
    let dst = (g.num_vertices - 1) as u32;

    // Gunrock SSSP, TWC strategy (the paper's pick for mesh graphs).
    let mut cfg = Config::default();
    cfg.strategy = Some(StrategyKind::Twc);
    let (p, r) = sssp::sssp(&g, src, &cfg);
    println!(
        "gunrock SSSP (TWC + near/far delta={}): {:.2} ms, {} iterations",
        cfg.sssp_delta,
        r.runtime_ms,
        r.num_iterations()
    );

    // Baselines.
    let (want, dijkstra_ms) = time_ms(|| dijkstra(&g, src));
    let ((bf, relax), bf_ms) = time_ms(|| bellman_ford(&g, src, cfg.effective_threads()));
    assert_eq!(p.dist, want, "distance mismatch vs Dijkstra");
    assert_eq!(bf, want, "distance mismatch vs Bellman-Ford");
    println!("dijkstra (serial oracle): {dijkstra_ms:.2} ms");
    println!("bellman-ford (Ligra-style): {bf_ms:.2} ms ({relax} relaxations)");

    // Route extraction via predecessors.
    if p.dist[dst as usize] < sssp::INFINITY_DIST {
        let mut route = vec![dst];
        let mut cur = dst;
        while cur != src {
            cur = p.preds[cur as usize] as u32;
            route.push(cur);
        }
        route.reverse();
        println!(
            "route {src} -> {dst}: distance {}, {} hops (first 8: {:?})",
            p.dist[dst as usize],
            route.len() - 1,
            &route[..route.len().min(8)]
        );
    } else {
        println!("{dst} unreachable from {src}");
    }
}
