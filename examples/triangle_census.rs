//! Triangle census: TC and clustering coefficients across topology
//! classes, comparing the two Gunrock variants (full vs filtered
//! intersection, Fig 25's series) against the Schank-Wagner baseline.
//!
//!     cargo run --release --example triangle_census

use gunrock::baselines::tc_forward::tc_forward;
use gunrock::config::Config;
use gunrock::graph::datasets;
use gunrock::primitives::tc;
use gunrock::util::timer::time_ms;

fn main() {
    let cfg = Config::default();
    println!("dataset                triangles   full(ms)  filtered(ms)  baseline(ms)  speedup");
    for name in ["smallworld", "hollywood-09", "rgg_1k", "kron_g500-logn10"] {
        let g = datasets::load(name, false);
        let (want, base_ms) = time_ms(|| tc_forward(&g));
        let (full, full_r) = tc::tc_intersect_full(&g, &cfg);
        let (filt, filt_r) = tc::tc_intersect_filtered(&g, &cfg);
        assert_eq!(full.triangles, want, "{name}: full variant disagrees with baseline");
        assert_eq!(filt.triangles, want, "{name}: filtered variant disagrees with baseline");
        println!(
            "{:22} {:>9}   {:>7.2}   {:>10.2}   {:>10.2}   {:>6.2}x",
            name,
            want,
            full_r.runtime_ms,
            filt_r.runtime_ms,
            base_ms,
            base_ms / filt_r.runtime_ms
        );
    }

    // clustering coefficients on the triangle-dense analog
    let g = datasets::load("smallworld", false);
    let cc = tc::clustering_coefficient(&g, &cfg);
    let avg: f64 = cc.iter().sum::<f64>() / cc.len() as f64;
    println!("\nsmallworld average clustering coefficient: {avg:.4}");
}
