//! Quickstart: generate a small scale-free graph, run BFS through the
//! Gunrock programming model, and inspect the frontier statistics.
//!
//!     cargo run --release --example quickstart

use gunrock::config::Config;
use gunrock::graph::generators::{rmat, rmat::RmatParams};
use gunrock::graph::properties;
use gunrock::harness::suite;
use gunrock::primitives::bfs;

fn main() {
    // 1. A workload: R-MAT with the paper's Graph500 initiator.
    let g = rmat(&RmatParams { scale: 12, edge_factor: 16, ..Default::default() });
    let props = properties::analyze(&g);
    println!(
        "graph: {} vertices, {} edges, max degree {}, pseudo-diameter {}",
        props.vertices, props.edges, props.max_degree, props.pseudo_diameter
    );

    // 2. Configure the framework: direction-optimized traversal on.
    let mut cfg = Config::default();
    cfg.direction_optimized = true;

    // 3. Run BFS from the highest-degree vertex.
    let src = suite::pick_source(&g);
    let (problem, stats) = bfs::bfs(&g, src, &cfg);

    let reached = problem.labels.iter().filter(|&&d| d != bfs::INFINITY_DEPTH).count();
    println!(
        "BFS from {src}: reached {reached}/{} vertices in {} iterations",
        g.num_vertices,
        stats.result.num_iterations()
    );
    println!(
        "runtime {:.3} ms | {:.1} MTEPS | warp efficiency {:.1}% | {} push + {} pull iterations",
        stats.result.runtime_ms,
        stats.result.mteps(),
        stats.result.warp_efficiency * 100.0,
        stats.push_iterations,
        stats.pull_iterations
    );

    // 4. Per-iteration frontier trace (the paper's Fig 22-23 raw data).
    println!("\niter  direction  input    output   edges");
    for it in &stats.result.iterations {
        println!(
            "{:>4}  {:9}  {:>7}  {:>7}  {:>8}",
            it.iteration,
            if it.pull { "pull" } else { "push" },
            it.input_frontier,
            it.output_frontier,
            it.edges_this_iter
        );
    }
}
